//! The ChangeDetector (paper §7.2): a statistical binary classifier that
//! "simply uses the Welch's statistical test to distinguish steady state
//! processing from workload transitions. This classifier does not
//! require off-line training."
//!
//! Decision rule: between two consecutive observation windows, run a
//! Welch t-test per feature (from the windows' stored mean/var — the
//! same moments the `welch_stats` L1 kernel emits); a transition is
//! flagged when at least `min_changed_features` features reject at
//! significance `alpha` (a crude Bonferroni against the 16-way multiple
//! comparison).

use crate::features::{ObservationWindow, NUM_FEATURES};
use crate::stats::welch_t_test_from_moments;

#[derive(Debug, Clone)]
pub struct ChangeDetectorConfig {
    /// Per-feature two-sided significance level.
    pub alpha: f64,
    /// Features that must individually reject before we call a change.
    pub min_changed_features: usize,
}

impl Default for ChangeDetectorConfig {
    fn default() -> Self {
        ChangeDetectorConfig { alpha: 0.001, min_changed_features: 3 }
    }
}

/// Stateless core: is there a statistically meaningful change between
/// two windows?
pub fn windows_differ(
    a: &ObservationWindow,
    b: &ObservationWindow,
    config: &ChangeDetectorConfig,
) -> bool {
    changed_features(a, b, config) >= config.min_changed_features
}

/// Number of features whose Welch test rejects between `a` and `b`.
pub fn changed_features(
    a: &ObservationWindow,
    b: &ObservationWindow,
    config: &ChangeDetectorConfig,
) -> usize {
    let mut changed = 0;
    for i in 0..NUM_FEATURES {
        let r = welch_t_test_from_moments(
            a.mean[i],
            a.var[i] * a.samples as f64 / (a.samples as f64 - 1.0),
            a.samples,
            b.mean[i],
            b.var[i] * b.samples as f64 / (b.samples as f64 - 1.0),
            b.samples,
        );
        if r.p < config.alpha {
            changed += 1;
        }
    }
    changed
}

/// Streaming change detector: feed windows in order; `observe` returns
/// true when the new window differs from its predecessor.
#[derive(Debug)]
pub struct ChangeDetector {
    config: ChangeDetectorConfig,
    prev: Option<ObservationWindow>,
}

impl ChangeDetector {
    pub fn new(config: ChangeDetectorConfig) -> ChangeDetector {
        ChangeDetector { config, prev: None }
    }

    pub fn with_defaults() -> ChangeDetector {
        ChangeDetector::new(ChangeDetectorConfig::default())
    }

    /// Returns true if `w` starts/continues a transition (differs from
    /// the previous window). The first window is never a change.
    pub fn observe(&mut self, w: &ObservationWindow) -> bool {
        let changed = match &self.prev {
            Some(p) => windows_differ(p, w, &self.config),
            None => false,
        };
        self.prev = Some(w.clone());
        changed
    }

    pub fn reset(&mut self) {
        self.prev = None;
    }

    /// Batch mode (Algorithm 2: "run ChangeDetector.batch() to identify
    /// transition windows") — same logic as streaming, applied to a
    /// recorded window sequence. Returns a flag per window.
    pub fn batch(
        windows: &[ObservationWindow],
        config: &ChangeDetectorConfig,
    ) -> Vec<bool> {
        let mut det = ChangeDetector::new(config.clone());
        windows.iter().map(|w| det.observe(w)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::monitor::{aggregate_trace, MonitorConfig};
    use crate::workloadgen::{tour_schedule, Generator};

    fn window(mean_val: f64, var_val: f64, idx: u64) -> ObservationWindow {
        ObservationWindow {
            index: idx,
            time: idx as f64,
            samples: 30,
            mean: [mean_val; NUM_FEATURES],
            var: [var_val; NUM_FEATURES],
            truth: None,
        }
    }

    #[test]
    fn identical_windows_no_change() {
        let mut det = ChangeDetector::with_defaults();
        assert!(!det.observe(&window(5.0, 1.0, 0)));
        assert!(!det.observe(&window(5.0, 1.0, 1)));
    }

    #[test]
    fn large_shift_detected() {
        let mut det = ChangeDetector::with_defaults();
        det.observe(&window(5.0, 1.0, 0));
        assert!(det.observe(&window(50.0, 1.0, 1)));
    }

    #[test]
    fn small_noise_not_detected() {
        let mut det = ChangeDetector::with_defaults();
        det.observe(&window(5.0, 4.0, 0));
        assert!(!det.observe(&window(5.2, 4.0, 1)));
    }

    #[test]
    fn batch_flags_real_transitions() {
        let mut g = Generator::with_default_config(0);
        let t = g.generate(&tour_schedule(120, &[0, 2, 5]));
        let mcfg = MonitorConfig { window_size: 12 };
        let ws = aggregate_trace(&t, &mcfg);
        let flags =
            ChangeDetector::batch(&ws, &ChangeDetectorConfig::default());
        let truth = crate::monitor::transition_truth(&t, &mcfg);
        // every true transition region must be flagged within +-1 window
        for (i, &is_t) in truth.iter().enumerate() {
            if is_t {
                let hit = (i.saturating_sub(1)..=(i + 1).min(flags.len() - 1))
                    .any(|k| flags[k]);
                assert!(hit, "transition at window {i} missed");
            }
        }
        // and steady interior windows are mostly quiet
        let quiet = flags
            .iter()
            .zip(&truth)
            .filter(|&(f, t)| !t && !f)
            .count();
        let steady = truth.iter().filter(|&&t| !t).count();
        assert!(
            quiet as f64 / steady as f64 > 0.9,
            "{quiet}/{steady} steady windows quiet"
        );
    }

    #[test]
    fn reset_forgets_history() {
        let mut det = ChangeDetector::with_defaults();
        det.observe(&window(5.0, 1.0, 0));
        det.reset();
        // first window after reset can't be a change
        assert!(!det.observe(&window(50.0, 1.0, 1)));
    }
}
