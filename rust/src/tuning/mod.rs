//! The per-tenant tuning plane: the layer that closes the multi-tenant
//! MAPE-K loop end to end.
//!
//! PRs 3–4 scaled the *identification* side (sharded stream layer,
//! amortized off-line cycles); this module scales the *tuning* side —
//! the paper's §6.4 Algorithm 1 headline — to K tenants on one shared
//! cluster:
//!
//! * **Monitor / Analyze** — every tenant's metric stream flows through
//!   the [`MultiTenantCoordinator`]'s router shards (adaptive off-line
//!   cadence included);
//! * **Plan** — one [`KermitPlugin`] per tenant, each reading its own
//!   tenant's context stream (the same `Arc` the shard publishes into)
//!   and all sharing the [`SharedWorkloadDb`] knowledge plane;
//! * **Execute** — the plane implements
//!   [`TenantRmPlugin`], so the multi-tenant simcluster's resource
//!   manager calls straight into each tenant's Algorithm 1 at the
//!   interception point and applies the chosen config to the job's
//!   containers;
//! * **Knowledge** — optima are stored once and cache-hit by *every*
//!   tenant: when tenant A's search converges, tenant B's next request
//!   for the same workload label is a `CacheHit` with zero probes paid
//!   (and a tenant mid-search for that label abandons its session —
//!   the plug-in's cross-tenant search dedup). This is the
//!   recurring-workload economics of Tuneful-style amortized tuning on
//!   a shared cluster.
//!
//! `experiments::tuning_plane` scores the closed loop: tuned-vs-default
//! speedup, cluster-wide cache-hit ratio, and probes saved versus K
//! independent single-tenant loops.

use crate::coordinator::{
    CadencePolicy, CoordinatorConfig, MultiTenantCoordinator,
    MultiTenantReport,
};
use crate::explorer::ExplorerConfig;
use crate::knowledge::persist::{
    KnowledgeStore, RecoveryReport, SnapshotCodec, WalRecord,
};
use crate::obs::{DecisionTrace, Registry};
use crate::online::{
    ChoiceKind, KermitPlugin, PluginStats, ResiliencePolicy, UNKNOWN,
};
use crate::simcluster::config_space::{ConfigIndex, TuningConfig};
use crate::simcluster::multi::{
    MultiClusterEngine, MultiEngineConfig, MultiSimResult, TenantRmPlugin,
};
use crate::simcluster::rm::{ResourceManager, ResourceRequest};
use crate::simcluster::JobSpec;
use crate::stream::{IngestConfig, IngestHandle, PumpStats, TenantId};
use crate::workloadgen::Sample;
use std::collections::BTreeMap;

/// Tuning-plane configuration.
#[derive(Clone)]
pub struct TuningPlaneConfig {
    pub coordinator: CoordinatorConfig,
    /// Explorer budgets handed to every tenant's plug-in.
    pub explorer: ExplorerConfig,
    /// Plug-in context staleness bound (Algorithm 1's error path).
    pub max_context_age: f64,
    /// Off-line cadence. Defaults to adaptive: a tenant whose recent
    /// windows are mostly UNKNOWN (new tenant, or drift suspicion)
    /// triggers an early cycle instead of waiting out the fixed union
    /// interval.
    pub cadence: CadencePolicy,
    /// Fault hardening knobs (defaults keep healthy runs unchanged).
    pub resilience: TuningResilience,
}

impl Default for TuningPlaneConfig {
    fn default() -> Self {
        TuningPlaneConfig {
            coordinator: CoordinatorConfig::default(),
            explorer: ExplorerConfig::default(),
            max_context_age: 120.0,
            cadence: CadencePolicy::Adaptive {
                unknown_rate: 0.7,
                min_windows: 8,
            },
            resilience: TuningResilience::default(),
        }
    }
}

/// How the tuning plane degrades under faults: decision timeouts keep
/// the per-tenant pending app→label map from wedging on a measurement
/// that will never arrive; the poison detector quarantines a stored
/// optimum whose live cache-hit runs are wildly slower than the
/// duration the search measured.
#[derive(Debug, Clone)]
pub struct TuningResilience {
    /// A decision older than this (sim seconds) with no completion or
    /// failure is written off as a failed probe.
    pub decision_timeout: f64,
    /// Per-plug-in hardening (session caps, probe-failure backoff).
    pub plugin: ResiliencePolicy,
    /// A full-fleet cache-hit run slower than `poison_factor` x the
    /// stored measured optimum counts as one poisoning strike.
    pub poison_factor: f64,
    /// Strikes before the label is quarantined.
    pub poison_strikes: u32,
}

impl Default for TuningResilience {
    fn default() -> Self {
        TuningResilience {
            decision_timeout: 3600.0,
            plugin: ResiliencePolicy::default(),
            poison_factor: 4.0,
            poison_strikes: 2,
        }
    }
}

/// Cap on the per-tenant decision log (telemetry; oldest half dropped
/// on overflow, like the stream layer's shard logs — the durable
/// per-kind counts live in `PluginStats`).
const CHOICE_LOG_CAP: usize = 4096;

/// Cadence of the durable knowledge plane when a store is attached:
/// the mutation journal is flushed to the WAL every
/// `flush_every_decisions` Algorithm-1 events (decisions +
/// completions), and every `snapshot_every_flushes` flushes the DB is
/// folded into a new snapshot generation. Smaller numbers shrink the
/// crash-loss window at the cost of more fsyncs.
#[derive(Debug, Clone, Copy)]
pub struct PersistencePolicy {
    pub flush_every_decisions: u32,
    pub snapshot_every_flushes: u32,
}

impl Default for PersistencePolicy {
    fn default() -> Self {
        PersistencePolicy {
            flush_every_decisions: 8,
            snapshot_every_flushes: 16,
        }
    }
}

/// What a pending decision was (determines the completion edge).
#[derive(Debug, Clone, Copy)]
enum PendingKind {
    /// The measurement at completion must feed exactly this label's
    /// search session.
    Probe { label: u32 },
    /// A served optimum under observation by the poison detector;
    /// `expected` is the duration the search measured for it.
    CacheHit { label: u32, expected: Option<f64> },
}

/// One outstanding decision (app granted but not yet completed/failed).
#[derive(Debug, Clone, Copy)]
struct PendingDecision {
    kind: PendingKind,
    decided_at: f64,
    /// Executors Algorithm 1 asked for vs. what the RM granted — the
    /// poison detector only scores *full-fleet* runs (a degraded fleet
    /// legitimately runs slow; blaming the stored optimum for it would
    /// quarantine healthy entries).
    asked: u32,
    granted: u32,
}

/// One tenant's slice of the tuning plane.
struct TenantTuning {
    plugin: KermitPlugin,
    /// app_id -> the decision made for it, awaiting its outcome.
    pending: BTreeMap<u64, PendingDecision>,
    /// Decision log in request order (telemetry + tests; capped at
    /// [`CHOICE_LOG_CAP`]).
    choices: Vec<ChoiceKind>,
}

/// Aggregate report of one tuning-plane run.
#[derive(Debug, Clone, Default)]
pub struct TuningRunReport {
    pub sim: MultiSimResult,
    /// Identification-side report with `tenant_stats` filled in.
    pub multi: MultiTenantReport,
    /// Cache hits served with an optimum a *different* tenant paid the
    /// search for — the cross-tenant reuse observable.
    pub cross_tenant_hits: usize,
    /// Probes actually paid across all tenants (global + local).
    pub probes_paid: usize,
    pub searches_completed: usize,
    pub searches_abandoned: usize,
    /// Searches written off without a trusted optimum (fault hardening).
    pub searches_failed: usize,
    /// Probe decisions expired by the decision timeout.
    pub probes_timed_out: usize,
    /// Probe decisions whose job died before completing.
    pub probe_jobs_failed: usize,
    /// Labels quarantined by the cache-poisoning detector.
    pub labels_quarantined: usize,
    /// Plug-ins still waiting on a probe measurement after the run
    /// fully drained — must be zero (the no-livelock guarantee).
    pub livelocked_sessions: usize,
}

impl TuningRunReport {
    pub fn makespan(&self) -> f64 {
        self.sim.makespan
    }

    pub fn cache_hit_ratio(&self) -> f64 {
        self.multi.cluster_cache_hit_ratio()
    }
}

/// The assembled per-tenant tuning plane.
pub struct TuningPlane {
    /// The identification loop underneath (router shards, shared DB,
    /// consolidated off-line cycle, adaptive cadence).
    pub coord: MultiTenantCoordinator,
    tenants: BTreeMap<TenantId, TenantTuning>,
    explorer: ExplorerConfig,
    max_context_age: f64,
    /// label -> tenant whose search stored the optimum.
    search_owner: BTreeMap<u32, TenantId>,
    /// Cache hits on an optimum some other tenant searched for.
    pub cross_tenant_hits: usize,
    /// Windows observed across all ticks driven by this plane.
    windows_observed: usize,
    /// Fault-hardening knobs (copied into each tenant's plug-in).
    pub resilience: TuningResilience,
    /// label -> consecutive poisoning strikes.
    strikes: BTreeMap<u32, u32>,
    /// Probe decisions expired by the decision timeout.
    pub probes_timed_out: usize,
    /// Probe decisions whose job the fault layer killed.
    pub probe_jobs_failed: usize,
    /// Labels the poison detector quarantined.
    pub labels_quarantined: usize,
    /// Decisions served through the degraded path (transport-impaired
    /// tenant: last-known label, safe config, no probe).
    pub degraded_decisions: usize,
    /// Attached durable knowledge store (None: in-memory only — every
    /// pre-existing caller pays nothing).
    store: Option<KnowledgeStore>,
    /// Flush / snapshot cadence when a store is attached.
    pub persistence: PersistencePolicy,
    events_since_flush: u32,
    flushes_since_snapshot: u32,
    /// Persistence failures absorbed (full disk, EPERM): the plane
    /// degrades to in-memory behaviour, it never panics mid-decision.
    pub persist_errors: usize,
    /// Decision tracing (None = off, zero overhead). Spans cover the
    /// decide → probe → measure path per tenant; persist flushes are
    /// noted globally.
    trace: Option<DecisionTrace>,
    /// Last decision time seen — the timestamp persist notes carry
    /// (persistence entry points have no sim clock of their own).
    trace_clock: f64,
}

/// Stable span-kind names for decision tracing.
fn choice_kind_str(kind: ChoiceKind) -> &'static str {
    match kind {
        ChoiceKind::Default => "default",
        ChoiceKind::CacheHit => "cache_hit",
        ChoiceKind::GlobalProbe => "global_probe",
        ChoiceKind::LocalProbe => "local_probe",
    }
}

fn label_str(label: u32) -> String {
    if label == UNKNOWN {
        "UNKNOWN".to_string()
    } else {
        label.to_string()
    }
}

impl TuningPlane {
    pub fn new(config: TuningPlaneConfig) -> TuningPlane {
        let mut coord = MultiTenantCoordinator::new(config.coordinator);
        coord.cadence = config.cadence;
        TuningPlane {
            coord,
            tenants: BTreeMap::new(),
            explorer: config.explorer,
            max_context_age: config.max_context_age,
            search_owner: BTreeMap::new(),
            cross_tenant_hits: 0,
            windows_observed: 0,
            resilience: config.resilience,
            strikes: BTreeMap::new(),
            probes_timed_out: 0,
            probe_jobs_failed: 0,
            labels_quarantined: 0,
            degraded_decisions: 0,
            store: None,
            persistence: PersistencePolicy::default(),
            events_since_flush: 0,
            flushes_since_snapshot: 0,
            persist_errors: 0,
            trace: None,
            trace_clock: 0.0,
        }
    }

    /// Enable telemetry: the coordinator's router shards get
    /// per-tenant observe counters registered in `reg`, and
    /// [`TuningPlane::scrape`] bridges everything else on demand.
    /// Counting never changes a decision.
    pub fn enable_telemetry(&mut self, reg: &Registry) {
        self.coord.enable_telemetry(reg);
    }

    /// Enable decision tracing with a per-tenant ring of `cap` spans.
    pub fn enable_tracing(&mut self, cap: usize) {
        self.trace = Some(DecisionTrace::new(cap));
    }

    /// The decision trace, when tracing is enabled.
    pub fn decision_trace(&self) -> Option<&DecisionTrace> {
        self.trace.as_ref()
    }

    /// Bridge the plane's counters — per-tenant plug-in stats, tuning
    /// loop-health counters, the coordinator/supervisor/ingest layers
    /// underneath, and the durable store when attached — into `reg`.
    /// Everything exported here is driven by the deterministic sim, so
    /// chaos scenarios can evaluate alert rules over it reproducibly.
    /// Deliberately NOT exported: `linalg::pool` stats, which are
    /// process-global — bridge them with `PoolStats::export_metrics`
    /// at whatever scope makes sense for the caller.
    pub fn scrape(&self, reg: &Registry) {
        for (t, tt) in &self.tenants {
            tt.plugin.stats.export_metrics(reg, &t.0.to_string());
        }
        let c = |name: &str, help: &str, v: usize| {
            reg.counter(name, help, &[]).set_total(v as u64);
        };
        c(
            "kermit_tuning_cross_tenant_hits_total",
            "Cache hits served with an optimum another tenant paid for.",
            self.cross_tenant_hits,
        );
        c(
            "kermit_tuning_windows_observed_total",
            "Windows observed across all ticks driven by this plane.",
            self.windows_observed,
        );
        c(
            "kermit_tuning_probes_timed_out_total",
            "Probe decisions expired by the decision timeout.",
            self.probes_timed_out,
        );
        c(
            "kermit_tuning_probe_jobs_failed_total",
            "Probe decisions whose job died before completing.",
            self.probe_jobs_failed,
        );
        c(
            "kermit_tuning_degraded_decisions_total",
            "Decisions served through the degraded (impaired-ingest) path.",
            self.degraded_decisions,
        );
        c(
            "kermit_persist_errors_total",
            "Persistence failures absorbed (store kept degraded, not down).",
            self.persist_errors,
        );
        // one quarantine ledger across both quarantine paths: the live
        // poison detector and the off-line integrity audit
        c(
            "kermit_knowledge_quarantines_total",
            "Knowledge-plane entries quarantined (poison detector + audit).",
            self.labels_quarantined + self.coord.db_quarantined,
        );
        reg.gauge(
            "kermit_tuning_pending_decisions",
            "Decisions awaiting completion across all tenants.",
            &[],
        )
        .set(self.pending_decisions() as f64);
        self.coord.export_metrics(reg);
        if let Some(store) = &self.store {
            store.stats.export_metrics(reg);
        }
    }

    /// Open a tuning plane on a durable knowledge store: recover the
    /// DB (newest verifying snapshot + WAL replay), install it as the
    /// shared knowledge plane, and attach the store so every further
    /// mutation is journaled. A restarted deployment serves recovered
    /// optima as cache hits from job one — zero probes re-paid for
    /// anything already learned.
    pub fn open_durable(
        config: TuningPlaneConfig,
        dir: &std::path::Path,
        codec: Box<dyn SnapshotCodec>,
    ) -> crate::util::error::Result<(TuningPlane, RecoveryReport)> {
        let (store, db, report) = KnowledgeStore::open(dir, codec)?;
        let mut plane = TuningPlane::new(config);
        plane.coord.install_db(db);
        plane.attach_store(store);
        Ok((plane, report))
    }

    /// Attach an opened store and start journaling DB mutations.
    pub fn attach_store(&mut self, store: KnowledgeStore) {
        self.coord.db.write().unwrap().enable_journal();
        self.store = Some(store);
    }

    /// The attached store (chaos scenarios arm faults through this).
    pub fn store_mut(&mut self) -> Option<&mut KnowledgeStore> {
        self.store.as_mut()
    }

    pub fn store(&self) -> Option<&KnowledgeStore> {
        self.store.as_ref()
    }

    /// Drain the DB journal into the WAL (fsynced). Errors are counted
    /// in `persist_errors`, never raised: losing durability degrades,
    /// it must not take the decision path down with it.
    pub fn persist_flush(&mut self) {
        self.events_since_flush = 0;
        if self.store.is_none() {
            return;
        }
        let journal = self.coord.db.write().unwrap().take_journal();
        if journal.is_empty() {
            return;
        }
        if let Some(tr) = self.trace.as_mut() {
            tr.note_persist(self.trace_clock, "wal_flush", journal.len() as u64);
        }
        let store = self.store.as_mut().unwrap();
        if store.append_all(&journal).is_err() {
            self.persist_errors += 1;
        }
    }

    /// Flush, then fold the DB into a new snapshot generation.
    pub fn persist_snapshot(&mut self) {
        self.persist_flush();
        self.flushes_since_snapshot = 0;
        let Some(store) = self.store.as_mut() else { return };
        let (failed, entries) = {
            let db = self.coord.db.read().unwrap();
            (store.snapshot(&db).is_err(), db.len() as u64)
        };
        if failed {
            self.persist_errors += 1;
        }
        if let Some(tr) = self.trace.as_mut() {
            tr.note_persist(self.trace_clock, "snapshot", entries);
        }
    }

    /// Cadenced persistence, called once per Algorithm-1 event.
    fn persist_tick(&mut self) {
        if self.store.is_none() {
            return;
        }
        self.events_since_flush += 1;
        if self.events_since_flush
            >= self.persistence.flush_every_decisions
        {
            self.persist_flush();
            self.flushes_since_snapshot += 1;
            if self.flushes_since_snapshot
                >= self.persistence.snapshot_every_flushes
            {
                self.persist_snapshot();
            }
        }
    }

    /// Clean shutdown: flush the journal and write a final snapshot.
    pub fn shutdown(&mut self) {
        if self.store.is_some() {
            self.persist_snapshot();
        }
    }

    /// Kill the plane the way a crash would: no final flush, no
    /// snapshot — un-journaled mutations are lost, exactly what a real
    /// crash loses. Armed WAL-tail faults fire on the way down.
    pub fn crash(mut self) {
        if let Some(store) = self.store.take() {
            store.simulate_crash();
        }
    }

    /// Ensure tenant `t` exists: a router shard in the coordinator and
    /// a plug-in wired to that shard's context stream plus the shared
    /// knowledge plane.
    pub fn ensure_tenant(&mut self, t: TenantId) {
        self.coord.ensure_tenant(t);
        if !self.tenants.contains_key(&t) {
            let ctx = self
                .coord
                .router()
                .shard(t)
                .expect("shard just ensured")
                .context
                .clone();
            let mut plugin = KermitPlugin::new(self.coord.db.clone(), ctx);
            plugin.explorer_config = self.explorer.clone();
            plugin.max_context_age = self.max_context_age;
            plugin.resilience = self.resilience.plugin.clone();
            self.tenants.insert(
                t,
                TenantTuning {
                    plugin,
                    pending: BTreeMap::new(),
                    choices: Vec::new(),
                },
            );
        }
    }

    pub fn n_tenants(&self) -> usize {
        self.tenants.len()
    }

    /// Tenant `t`'s plug-in stats (None before `ensure_tenant`).
    pub fn stats(&self, t: TenantId) -> Option<&PluginStats> {
        self.tenants.get(&t).map(|tt| &tt.plugin.stats)
    }

    /// Tenant `t`'s decision log in request order.
    pub fn choices(&self, t: TenantId) -> Option<&[ChoiceKind]> {
        self.tenants.get(&t).map(|tt| tt.choices.as_slice())
    }

    /// Algorithm 1 for tenant `t` at time `now` (`app_id` keys the
    /// probe-measurement correlation). The plane resolves the label
    /// once, runs the tenant's plug-in, and tracks the cross-tenant
    /// reuse bookkeeping (who paid for which optimum).
    pub fn decide(
        &mut self,
        t: TenantId,
        app_id: u64,
        now: f64,
    ) -> (ConfigIndex, ChoiceKind) {
        self.ensure_tenant(t);
        // first, write off any decision the cluster never answered —
        // a faulted job must not wedge this tenant's pending map (and
        // through it the plug-in's outstanding probe) forever
        self.expire_stale(t, now);
        // a tenant whose ingest transport is impaired (partitioned /
        // wedged — the supervisor's verdict) gets the stale-but-safe
        // path: last-known label, trusted config or default, and NO
        // probes — a probe measured through a broken transport would
        // poison the knowledge plane. Probing re-arms by itself once
        // the supervisor scores the tenant healthy again.
        self.trace_clock = now;
        if self.coord.ingest_impaired(t) {
            let label = self.coord.last_known_label(t).unwrap_or(UNKNOWN);
            self.degraded_decisions += 1;
            let tt = self.tenants.get_mut(&t).unwrap();
            let (config, kind) = tt.plugin.degraded_choice(label);
            tt.choices.push(kind);
            if tt.choices.len() > CHOICE_LOG_CAP {
                tt.choices.drain(..CHOICE_LOG_CAP / 2);
            }
            if let Some(tr) = self.trace.as_mut() {
                // no measurement is coming back on this path: the span
                // opens and closes at the decision edge
                tr.open(t.0, app_id, now, "degraded", &label_str(label));
                tr.close(t.0, app_id, now, "served_stale", None);
            }
            self.persist_tick();
            return (config, kind);
        }
        let tt = self.tenants.get_mut(&t).unwrap();
        let label = tt.plugin.current_label(now);
        let completed_before = tt.plugin.stats.searches_completed;
        let (config, kind) = tt.plugin.choose_config_for_label(label);
        if label != UNKNOWN {
            if tt.plugin.stats.searches_completed > completed_before {
                // this tenant's own search converged on this request
                // and persisted the optimum: it owns the label now —
                // overwrite, because after drift a *different* tenant
                // may have paid the re-search for a label somebody
                // else owned first. (The abandon path deliberately
                // does NOT touch ownership: the optimum it serves was
                // stored by whoever already owns the label.)
                self.search_owner.insert(label, t);
            }
            if kind == ChoiceKind::CacheHit
                && self.search_owner.get(&label).is_some_and(|o| *o != t)
            {
                self.cross_tenant_hits += 1;
            }
            let asked = config.to_config().num_executors;
            match kind {
                ChoiceKind::GlobalProbe | ChoiceKind::LocalProbe => {
                    tt.pending.insert(
                        app_id,
                        PendingDecision {
                            kind: PendingKind::Probe { label },
                            decided_at: now,
                            asked,
                            granted: 0,
                        },
                    );
                }
                ChoiceKind::CacheHit => {
                    // arm the poison detector: compare the live run
                    // against the duration the search measured
                    let expected = self
                        .coord
                        .db
                        .read()
                        .unwrap()
                        .get(label)
                        .and_then(|e| e.best_duration);
                    tt.pending.insert(
                        app_id,
                        PendingDecision {
                            kind: PendingKind::CacheHit { label, expected },
                            decided_at: now,
                            asked,
                            granted: 0,
                        },
                    );
                }
                ChoiceKind::Default => {}
            }
        }
        tt.choices.push(kind);
        if tt.choices.len() > CHOICE_LOG_CAP {
            tt.choices.drain(..CHOICE_LOG_CAP / 2);
        }
        if let Some(tr) = self.trace.as_mut() {
            tr.open(t.0, app_id, now, choice_kind_str(kind), &label_str(label));
            if matches!(kind, ChoiceKind::Default) {
                // defaults never get a completion edge routed back
                tr.close(t.0, app_id, now, "served", None);
            }
        }
        self.persist_tick();
        (config, kind)
    }

    /// Completion feedback for tenant `t`'s application `app_id`.
    pub fn complete(&mut self, t: TenantId, app_id: u64, duration: f64) {
        let Some(tt) = self.tenants.get_mut(&t) else { return };
        let Some(p) = tt.pending.remove(&app_id) else { return };
        let mut measured = None;
        match p.kind {
            PendingKind::Probe { label } => {
                tt.plugin.record_measurement(label, duration);
                measured = Some(label);
            }
            PendingKind::CacheHit { label, expected } => {
                // poison detection: a full-fleet run of the stored
                // optimum that is wildly slower than its measured
                // duration means the entry cannot be trusted
                if let (Some(exp), true) =
                    (expected, p.granted >= p.asked)
                {
                    if duration
                        > self.resilience.poison_factor * exp.max(1e-9)
                    {
                        let c = self.strikes.entry(label).or_insert(0);
                        *c += 1;
                        if *c >= self.resilience.poison_strikes {
                            self.strikes.remove(&label);
                            if self
                                .coord
                                .db
                                .write()
                                .unwrap()
                                .quarantine(label)
                            {
                                self.labels_quarantined += 1;
                            }
                        }
                    } else {
                        // a healthy full-fleet hit clears the streak
                        self.strikes.remove(&label);
                    }
                }
            }
        }
        if let Some(tr) = self.trace.as_mut() {
            // the sim clock isn't on this edge; decide-time plus the
            // measured duration is the deterministic completion stamp
            tr.close(
                t.0,
                app_id,
                p.decided_at + duration,
                "measured",
                Some(duration),
            );
        }
        if let Some(label) = measured {
            // paid probes go to the WAL as an audit trail (replay is a
            // state no-op — sessions are in-memory); appended directly
            // so the record carries the measurement even if the journal
            // is between flushes
            if let Some(store) = self.store.as_mut() {
                if store
                    .append(&WalRecord::Measurement { label, duration })
                    .is_err()
                {
                    self.persist_errors += 1;
                }
            }
        }
        self.persist_tick();
    }

    /// Expire tenant `t`'s decisions older than the decision timeout.
    /// An expired probe is fed to the plug-in as a failed measurement so
    /// its session can never livelock waiting for one.
    fn expire_stale(&mut self, t: TenantId, now: f64) {
        let timeout = self.resilience.decision_timeout;
        let Some(tt) = self.tenants.get_mut(&t) else { return };
        let stale: Vec<u64> = tt
            .pending
            .iter()
            .filter(|(_, p)| now - p.decided_at > timeout)
            .map(|(id, _)| *id)
            .collect();
        for id in stale {
            let p = tt.pending.remove(&id).unwrap();
            if let PendingKind::Probe { label } = p.kind {
                tt.plugin.fail_probe(label);
                self.probes_timed_out += 1;
            }
            if let Some(tr) = self.trace.as_mut() {
                tr.close(t.0, id, now, "timed_out", None);
            }
        }
    }

    /// Expire stale decisions across every tenant (end-of-run sweep —
    /// pass a `now` beyond the makespan plus the timeout to flush
    /// everything a faulted run left behind).
    pub fn reconcile(&mut self, now: f64) {
        let ids: Vec<TenantId> = self.tenants.keys().copied().collect();
        for t in ids {
            self.expire_stale(t, now);
        }
    }

    /// Plug-ins still waiting on a probe measurement. After `reconcile`
    /// this is the chaos lab's livelock observable and must be zero.
    pub fn livelocked_sessions(&self) -> usize {
        self.tenants
            .values()
            .filter(|tt| tt.plugin.outstanding_label().is_some())
            .count()
    }

    /// Outstanding decisions across all tenants.
    pub fn pending_decisions(&self) -> usize {
        self.tenants.values().map(|tt| tt.pending.len()).sum()
    }

    /// Tenants the plane currently tracks.
    pub fn tenant_ids(&self) -> Vec<TenantId> {
        self.tenants.keys().copied().collect()
    }

    /// Flush window batches still pending in the router shards.
    pub fn drain(&mut self) {
        self.windows_observed += self.coord.tick();
    }

    /// Attach an event-driven ingest front-end to the coordinator and
    /// return a producer handle (see
    /// [`MultiTenantCoordinator::attach_ingest`]). Front-end batching,
    /// router ticks, offline cycles, and tuning probes then all run on
    /// the one work-stealing executor.
    pub fn attach_ingest(&mut self, config: IngestConfig) -> IngestHandle {
        self.coord.attach_ingest(config)
    }

    /// Pump the attached front-end (drain queues → batch windows →
    /// tick), folding the tick's windows into this plane's observed
    /// count so reports and the offline cadence see front-end traffic
    /// exactly like direct ingest. `None` if nothing is attached.
    pub fn pump_ingest(&mut self) -> Option<PumpStats> {
        let (stats, n) = self.coord.pump_ingest()?;
        self.windows_observed += n;
        Some(stats)
    }

    /// Supervised pump with consumer-side faults in the loop: `wedged`
    /// lanes are skipped this pump (and the supervisor's retry backoff
    /// may skip more). See
    /// [`MultiTenantCoordinator::pump_ingest_supervised`].
    pub fn pump_ingest_wedged(
        &mut self,
        wedged: &[TenantId],
    ) -> Option<PumpStats> {
        let (stats, n) = self.coord.pump_ingest_supervised(wedged)?;
        self.windows_observed += n;
        Some(stats)
    }

    /// Transport reconcile: flush every sequence gap and parked sample,
    /// tick, and re-arm all demoted tenants (see
    /// [`MultiTenantCoordinator::reconcile_ingest`]). Call at heal /
    /// end-of-run, before [`TuningPlane::reconcile`].
    pub fn reconcile_ingest(&mut self) -> Option<PumpStats> {
        let (stats, n) = self.coord.reconcile_ingest()?;
        self.windows_observed += n;
        Some(stats)
    }

    /// Run the knowledge-plane integrity sweep (quarantines corrupt
    /// entries); returns the labels quarantined by this sweep.
    pub fn audit_knowledge(&mut self) -> Vec<u32> {
        self.coord.audit_knowledge()
    }

    /// Drive per-tenant job schedules through the shared simcluster
    /// with this plane as the RM plug-in hub: the full closed loop
    /// (monitor → analyze → plan → execute → knowledge) per tenant.
    pub fn run_schedules(
        &mut self,
        schedules: &[(TenantId, Vec<JobSpec>)],
        sim: MultiEngineConfig,
        seed: u64,
    ) -> TuningRunReport {
        let mut engine = MultiClusterEngine::new(
            ResourceManager::default_cluster(),
            sim,
            seed,
        );
        for (t, jobs) in schedules {
            self.ensure_tenant(*t);
            engine.push_jobs(*t, jobs);
        }
        let sim_result = engine.run(self);
        // drain whatever is still pending in the shards, then write off
        // any decision a faulted run left dangling
        self.windows_observed += self.coord.tick();
        self.reconcile(
            sim_result.makespan + self.resilience.decision_timeout + 1.0,
        );
        // a finished run's learnings are durable even between snapshots
        self.persist_flush();
        self.report(sim_result)
    }

    /// Build the aggregate report for a finished run.
    pub fn report(&self, sim: MultiSimResult) -> TuningRunReport {
        let mut multi = self.coord.report(self.windows_observed);
        multi.tenant_stats = self
            .tenants
            .iter()
            .map(|(t, tt)| (*t, tt.plugin.stats.clone()))
            .collect();
        let (probes, completed, abandoned, failed) =
            multi.tenant_stats.iter().fold(
                (0, 0, 0, 0),
                |(p, c, a, f), (_, s)| {
                    (
                        p + s.probes_paid(),
                        c + s.searches_completed,
                        a + s.searches_abandoned,
                        f + s.searches_failed,
                    )
                },
            );
        TuningRunReport {
            sim,
            multi,
            cross_tenant_hits: self.cross_tenant_hits,
            probes_paid: probes,
            searches_completed: completed,
            searches_abandoned: abandoned,
            searches_failed: failed,
            probes_timed_out: self.probes_timed_out,
            probe_jobs_failed: self.probe_jobs_failed,
            labels_quarantined: self.labels_quarantined,
            livelocked_sessions: self.livelocked_sessions(),
        }
    }
}

impl TenantRmPlugin for TuningPlane {
    fn on_samples(&mut self, t: TenantId, samples: &[Sample]) {
        self.coord.ingest(t, samples);
        self.windows_observed += self.coord.tick();
    }

    fn on_resource_request(
        &mut self,
        t: TenantId,
        req: &ResourceRequest,
    ) -> TuningConfig {
        let (config, _kind) = self.decide(t, req.app_id, req.time);
        config.to_config()
    }

    fn on_app_complete(
        &mut self,
        t: TenantId,
        app_id: u64,
        duration: f64,
        _now: f64,
    ) {
        self.complete(t, app_id, duration);
    }

    fn on_grant(&mut self, t: TenantId, app_id: u64, granted: u32) {
        if let Some(tt) = self.tenants.get_mut(&t) {
            if let Some(p) = tt.pending.get_mut(&app_id) {
                p.granted = granted;
            }
        }
    }

    fn on_app_fail(&mut self, t: TenantId, app_id: u64, now: f64) {
        // the job died (preemption without re-grant, or tenant churn):
        // no measurement is coming — resolve the decision NOW so the
        // plug-in's session sees a failed probe instead of wedging
        if let Some(tt) = self.tenants.get_mut(&t) {
            if let Some(p) = tt.pending.remove(&app_id) {
                if let PendingKind::Probe { label } = p.kind {
                    tt.plugin.fail_probe(label);
                    self.probe_jobs_failed += 1;
                }
                if let Some(tr) = self.trace.as_mut() {
                    tr.close(t.0, app_id, now, "failed", None);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::knowledge::Characterization;
    use crate::online::context::WorkloadContext;
    use crate::simcluster::perfmodel::job_duration;

    fn publish(plane: &TuningPlane, t: TenantId, label: u32, time: f64) {
        let ctx = plane
            .coord
            .router()
            .shard(t)
            .unwrap()
            .context
            .clone();
        ctx.lock().unwrap().publish(WorkloadContext {
            window_index: 0,
            time,
            current_label: label,
            pred_1: label,
            pred_5: label,
            pred_10: label,
        });
    }

    fn insert_workload(plane: &TuningPlane) -> u32 {
        let rows: Vec<Vec<f64>> = vec![vec![1.0; 4], vec![1.1; 4]];
        plane.coord.db.write().unwrap().insert_new(
            Characterization::from_vec_rows(&rows),
            vec![1.05; 4],
            2,
            false,
        )
    }

    #[test]
    fn late_joining_tenant_cache_hits_with_zero_probes() {
        // satellite pin, at K=4: tenant A pays the global search; a
        // late-joining tenant B with the same workload label gets
        // CacheHit on its FIRST in-sync request — zero probes paid by B
        // (and the remaining tenants reuse the same optimum too)
        let mut plane = TuningPlane::new(TuningPlaneConfig::default());
        let (a, b) = (TenantId(0), TenantId(1));
        plane.ensure_tenant(a);
        let label = insert_workload(&plane);
        publish(&plane, a, label, 0.0);

        // drive A's search to convergence (app ids arbitrary but unique)
        let mut app = 0u64;
        loop {
            let (c, kind) = plane.decide(a, app, 1.0);
            match kind {
                ChoiceKind::GlobalProbe => {
                    plane.complete(a, app, job_duration(2, &c.to_config()));
                }
                ChoiceKind::CacheHit => break,
                other => panic!("unexpected {other:?}"),
            }
            app += 1;
        }
        let a_stats = plane.stats(a).unwrap().clone();
        assert!(a_stats.probes_paid() > 5, "{a_stats:?}");
        assert_eq!(a_stats.searches_completed, 1);
        // A's own hit is not cross-tenant
        assert_eq!(plane.cross_tenant_hits, 0);

        // B joins late, sees the same workload label in its context
        plane.ensure_tenant(b);
        publish(&plane, b, label, 2.0);
        let (cfg_b, kind_b) = plane.decide(b, 999, 2.5);
        assert_eq!(kind_b, ChoiceKind::CacheHit, "B's first request");
        let stored = plane
            .coord
            .db
            .read()
            .unwrap()
            .get(label)
            .unwrap()
            .config
            .unwrap();
        assert_eq!(cfg_b, stored);
        let b_stats = plane.stats(b).unwrap();
        assert_eq!(b_stats.probes_paid(), 0, "B paid probes: {b_stats:?}");
        assert_eq!(b_stats.defaults, 0);
        assert_eq!(plane.cross_tenant_hits, 1);
        assert_eq!(plane.choices(b).unwrap(), &[ChoiceKind::CacheHit]);

        // two more late joiners: K=4 tenants total, one search paid
        for (k, t) in [TenantId(2), TenantId(3)].into_iter().enumerate() {
            plane.ensure_tenant(t);
            publish(&plane, t, label, 3.0);
            let (cfg, kind) = plane.decide(t, 1000 + k as u64, 3.5);
            assert_eq!(kind, ChoiceKind::CacheHit, "{t}");
            assert_eq!(cfg, stored, "{t}");
            assert_eq!(plane.stats(t).unwrap().probes_paid(), 0, "{t}");
        }
        assert_eq!(plane.n_tenants(), 4);
        assert_eq!(plane.cross_tenant_hits, 3);
        let report = plane.report(MultiSimResult::default());
        assert_eq!(report.multi.tenant_stats.len(), 4);
        // cluster-wide: A's probes dilute the ratio, the three reusing
        // tenants are pure cache hits
        assert!(report.cache_hit_ratio() > 0.0);
        assert_eq!(report.searches_completed, 1);
    }

    #[test]
    fn stale_context_falls_back_to_default_per_tenant() {
        // satellite pin: per-tenant staleness — tenant A in sync keeps
        // its real decision path while tenant B's stale context maps to
        // ChoiceKind::Default, visible per tenant in the report stats
        let mut plane = TuningPlane::new(TuningPlaneConfig::default());
        let (a, b) = (TenantId(0), TenantId(1));
        plane.ensure_tenant(a);
        plane.ensure_tenant(b);
        let label = insert_workload(&plane);
        publish(&plane, a, label, 1000.0);
        publish(&plane, b, label, 0.0); // will be stale at t=1000

        let (_, kind_a) = plane.decide(a, 0, 1000.0);
        assert_eq!(kind_a, ChoiceKind::GlobalProbe);
        plane.complete(a, 0, 100.0);
        let (cfg_b, kind_b) = plane.decide(b, 1, 1000.0);
        assert_eq!(kind_b, ChoiceKind::Default);
        assert_eq!(
            cfg_b,
            crate::simcluster::default_config_index()
        );

        let report = plane.report(MultiSimResult::default());
        let stats: BTreeMap<TenantId, PluginStats> =
            report.multi.tenant_stats.iter().cloned().collect();
        assert_eq!(stats[&a].defaults, 0);
        assert_eq!(stats[&a].global_probes, 1);
        assert_eq!(stats[&b].defaults, 1);
        assert_eq!(stats[&b].probes_paid(), 0);
    }

    #[test]
    fn concurrent_searchers_dedup_through_the_shared_plane() {
        // A and B both start searching the same label; A converges
        // first; B's next request abandons its session and cache-hits —
        // counted as a cross-tenant hit
        let mut plane = TuningPlane::new(TuningPlaneConfig::default());
        let (a, b) = (TenantId(0), TenantId(1));
        plane.ensure_tenant(a);
        plane.ensure_tenant(b);
        let label = insert_workload(&plane);
        publish(&plane, a, label, 0.0);
        publish(&plane, b, label, 0.0);

        // B probes once, then stalls (its jobs are long)
        let (cb, kb) = plane.decide(b, 1000, 1.0);
        assert_eq!(kb, ChoiceKind::GlobalProbe);
        plane.complete(b, 1000, job_duration(2, &cb.to_config()));

        // A searches to convergence
        let mut app = 0u64;
        loop {
            let (c, kind) = plane.decide(a, app, 1.0);
            match kind {
                ChoiceKind::GlobalProbe => {
                    plane.complete(a, app, job_duration(2, &c.to_config()))
                }
                ChoiceKind::CacheHit => break,
                other => panic!("unexpected {other:?}"),
            }
            app += 1;
        }

        // B's next request: session abandoned, A's optimum served
        let before = plane.stats(b).unwrap().probes_paid();
        let (_, kb2) = plane.decide(b, 2000, 2.0);
        assert_eq!(kb2, ChoiceKind::CacheHit);
        let b_stats = plane.stats(b).unwrap();
        assert_eq!(b_stats.searches_abandoned, 1);
        assert_eq!(b_stats.probes_paid(), before);
        assert!(plane.cross_tenant_hits >= 1);
    }

    #[test]
    fn probe_job_failure_unwedges_the_session() {
        // a probe's job dies mid-run (preemption without re-grant): the
        // failure edge must resolve the pending decision and feed the
        // session a failed probe — the tenant keeps deciding normally
        let mut plane = TuningPlane::new(TuningPlaneConfig::default());
        let t = TenantId(0);
        plane.ensure_tenant(t);
        let label = insert_workload(&plane);
        publish(&plane, t, label, 0.0);

        let (_, kind) = plane.decide(t, 7, 1.0);
        assert_eq!(kind, ChoiceKind::GlobalProbe);
        assert_eq!(plane.pending_decisions(), 1);
        assert_eq!(plane.livelocked_sessions(), 1);

        plane.on_app_fail(t, 7, 2.0);
        assert_eq!(plane.pending_decisions(), 0);
        assert_eq!(plane.livelocked_sessions(), 0, "session wedged");
        assert_eq!(plane.probe_jobs_failed, 1);
        // the next decision must not panic (no outstanding probe) —
        // it is either a fresh probe or a backoff fallback
        let (_, kind2) = plane.decide(t, 8, 3.0);
        assert!(matches!(
            kind2,
            ChoiceKind::GlobalProbe | ChoiceKind::Default
        ));
    }

    #[test]
    fn decision_timeout_expires_stale_probes() {
        let mut plane = TuningPlane::new(TuningPlaneConfig {
            resilience: TuningResilience {
                decision_timeout: 10.0,
                ..TuningResilience::default()
            },
            ..TuningPlaneConfig::default()
        });
        let t = TenantId(0);
        plane.ensure_tenant(t);
        let label = insert_workload(&plane);
        publish(&plane, t, label, 0.0);

        let (_, kind) = plane.decide(t, 1, 1.0);
        assert_eq!(kind, ChoiceKind::GlobalProbe);
        // far past the timeout, the next decision first expires the
        // stale probe (fed to the session as a failure) — no wedge, no
        // assert panic on the plug-in's outstanding guard
        publish(&plane, t, label, 50.0);
        let (_, kind2) = plane.decide(t, 2, 50.0);
        assert!(matches!(
            kind2,
            ChoiceKind::GlobalProbe | ChoiceKind::Default
        ));
        assert_eq!(plane.probes_timed_out, 1);
        let report = plane.report(MultiSimResult::default());
        assert_eq!(report.probes_timed_out, 1);
    }

    #[test]
    fn poisoned_cache_hit_quarantines_after_strikes() {
        let mut plane = TuningPlane::new(TuningPlaneConfig::default());
        let t = TenantId(0);
        plane.ensure_tenant(t);
        let label = insert_workload(&plane);
        publish(&plane, t, label, 0.0);
        // a stored optimum with a measured duration of 10.0...
        let cfg = ConfigIndex([2, 2, 2, 2, 2, 0]);
        plane
            .coord
            .db
            .write()
            .unwrap()
            .set_optimal_measured(label, cfg, 10.0);

        // ...served full-fleet but running 10x slower: two strikes
        for app in 0..2u64 {
            let (c, kind) = plane.decide(t, app, 1.0);
            assert_eq!(kind, ChoiceKind::CacheHit);
            assert_eq!(c, cfg);
            plane.on_grant(t, app, 99); // granted >= asked
            plane.complete(t, app, 100.0);
        }
        assert_eq!(plane.labels_quarantined, 1);
        assert!(plane
            .coord
            .db
            .read()
            .unwrap()
            .get(label)
            .unwrap()
            .quarantined);
        // the poisoned optimum is no longer served — fresh search
        let (_, kind) = plane.decide(t, 10, 2.0);
        assert_eq!(kind, ChoiceKind::GlobalProbe);
    }

    #[test]
    fn degraded_fleet_never_counts_as_poisoning() {
        // same slow runs, but the RM granted less than asked: the slow
        // duration is the cluster's fault, not the stored optimum's
        let mut plane = TuningPlane::new(TuningPlaneConfig::default());
        let t = TenantId(0);
        plane.ensure_tenant(t);
        let label = insert_workload(&plane);
        publish(&plane, t, label, 0.0);
        let cfg = ConfigIndex([2, 2, 2, 2, 2, 0]);
        plane
            .coord
            .db
            .write()
            .unwrap()
            .set_optimal_measured(label, cfg, 10.0);
        for app in 0..4u64 {
            let (_, kind) = plane.decide(t, app, 1.0);
            assert_eq!(kind, ChoiceKind::CacheHit);
            plane.on_grant(t, app, 1); // starved fleet
            plane.complete(t, app, 500.0);
        }
        assert_eq!(plane.labels_quarantined, 0);
        assert!(!plane
            .coord
            .db
            .read()
            .unwrap()
            .get(label)
            .unwrap()
            .quarantined);
    }

    #[test]
    fn durable_plane_recovers_optima_across_restart() {
        use crate::knowledge::persist::BinaryCodec;
        let dir = std::env::temp_dir().join("kermit_tuning_durable_test");
        std::fs::remove_dir_all(&dir).ok();

        let (mut plane, report) = TuningPlane::open_durable(
            TuningPlaneConfig::default(),
            &dir,
            Box::new(BinaryCodec),
        )
        .unwrap();
        assert_eq!(report.generation_loaded, None);
        let t = TenantId(0);
        plane.ensure_tenant(t);
        let label = insert_workload(&plane);
        publish(&plane, t, label, 0.0);
        let mut app = 0u64;
        loop {
            let (c, kind) = plane.decide(t, app, 1.0);
            match kind {
                ChoiceKind::GlobalProbe => {
                    plane.complete(t, app, job_duration(2, &c.to_config()))
                }
                ChoiceKind::CacheHit => break,
                other => panic!("unexpected {other:?}"),
            }
            app += 1;
        }
        assert_eq!(plane.persist_errors, 0);
        plane.shutdown();
        drop(plane);

        // restart: the recovered plane serves the learned optimum as a
        // cache hit on its FIRST request — zero probes re-paid
        let (mut plane2, report) = TuningPlane::open_durable(
            TuningPlaneConfig::default(),
            &dir,
            Box::new(BinaryCodec),
        )
        .unwrap();
        assert_eq!(report.generation_loaded, Some(1));
        plane2.ensure_tenant(t);
        publish(&plane2, t, label, 10.0);
        let (_, kind) = plane2.decide(t, 500, 10.5);
        assert_eq!(kind, ChoiceKind::CacheHit, "warm from job one");
        assert_eq!(plane2.stats(t).unwrap().probes_paid(), 0);
        assert_eq!(plane2.persist_errors, 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn unknown_label_never_creates_pending_entries() {
        let mut plane = TuningPlane::new(TuningPlaneConfig::default());
        let t = TenantId(0);
        plane.ensure_tenant(t);
        // no context published at all
        let (_, kind) = plane.decide(t, 0, 0.0);
        assert_eq!(kind, ChoiceKind::Default);
        // completion for an app with no pending probe is a no-op
        plane.complete(t, 0, 50.0);
        assert_eq!(plane.stats(t).unwrap().defaults, 1);
    }
}
