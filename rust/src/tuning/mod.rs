//! The per-tenant tuning plane: the layer that closes the multi-tenant
//! MAPE-K loop end to end.
//!
//! PRs 3–4 scaled the *identification* side (sharded stream layer,
//! amortized off-line cycles); this module scales the *tuning* side —
//! the paper's §6.4 Algorithm 1 headline — to K tenants on one shared
//! cluster:
//!
//! * **Monitor / Analyze** — every tenant's metric stream flows through
//!   the [`MultiTenantCoordinator`]'s router shards (adaptive off-line
//!   cadence included);
//! * **Plan** — one [`KermitPlugin`] per tenant, each reading its own
//!   tenant's context stream (the same `Arc` the shard publishes into)
//!   and all sharing the [`SharedWorkloadDb`] knowledge plane;
//! * **Execute** — the plane implements
//!   [`TenantRmPlugin`], so the multi-tenant simcluster's resource
//!   manager calls straight into each tenant's Algorithm 1 at the
//!   interception point and applies the chosen config to the job's
//!   containers;
//! * **Knowledge** — optima are stored once and cache-hit by *every*
//!   tenant: when tenant A's search converges, tenant B's next request
//!   for the same workload label is a `CacheHit` with zero probes paid
//!   (and a tenant mid-search for that label abandons its session —
//!   the plug-in's cross-tenant search dedup). This is the
//!   recurring-workload economics of Tuneful-style amortized tuning on
//!   a shared cluster.
//!
//! `experiments::tuning_plane` scores the closed loop: tuned-vs-default
//! speedup, cluster-wide cache-hit ratio, and probes saved versus K
//! independent single-tenant loops.

use crate::coordinator::{
    CadencePolicy, CoordinatorConfig, MultiTenantCoordinator,
    MultiTenantReport,
};
use crate::explorer::ExplorerConfig;
use crate::online::{ChoiceKind, KermitPlugin, PluginStats, UNKNOWN};
use crate::simcluster::config_space::{ConfigIndex, TuningConfig};
use crate::simcluster::multi::{
    MultiClusterEngine, MultiEngineConfig, MultiSimResult, TenantRmPlugin,
};
use crate::simcluster::rm::{ResourceManager, ResourceRequest};
use crate::simcluster::JobSpec;
use crate::stream::TenantId;
use crate::workloadgen::Sample;
use std::collections::BTreeMap;

/// Tuning-plane configuration.
#[derive(Clone)]
pub struct TuningPlaneConfig {
    pub coordinator: CoordinatorConfig,
    /// Explorer budgets handed to every tenant's plug-in.
    pub explorer: ExplorerConfig,
    /// Plug-in context staleness bound (Algorithm 1's error path).
    pub max_context_age: f64,
    /// Off-line cadence. Defaults to adaptive: a tenant whose recent
    /// windows are mostly UNKNOWN (new tenant, or drift suspicion)
    /// triggers an early cycle instead of waiting out the fixed union
    /// interval.
    pub cadence: CadencePolicy,
}

impl Default for TuningPlaneConfig {
    fn default() -> Self {
        TuningPlaneConfig {
            coordinator: CoordinatorConfig::default(),
            explorer: ExplorerConfig::default(),
            max_context_age: 120.0,
            cadence: CadencePolicy::Adaptive {
                unknown_rate: 0.7,
                min_windows: 8,
            },
        }
    }
}

/// Cap on the per-tenant decision log (telemetry; oldest half dropped
/// on overflow, like the stream layer's shard logs — the durable
/// per-kind counts live in `PluginStats`).
const CHOICE_LOG_CAP: usize = 4096;

/// One tenant's slice of the tuning plane.
struct TenantTuning {
    plugin: KermitPlugin,
    /// app_id -> label an outstanding probe decision was made for (the
    /// measurement at completion must feed exactly that label's
    /// session).
    pending: BTreeMap<u64, u32>,
    /// Decision log in request order (telemetry + tests; capped at
    /// [`CHOICE_LOG_CAP`]).
    choices: Vec<ChoiceKind>,
}

/// Aggregate report of one tuning-plane run.
#[derive(Debug, Clone, Default)]
pub struct TuningRunReport {
    pub sim: MultiSimResult,
    /// Identification-side report with `tenant_stats` filled in.
    pub multi: MultiTenantReport,
    /// Cache hits served with an optimum a *different* tenant paid the
    /// search for — the cross-tenant reuse observable.
    pub cross_tenant_hits: usize,
    /// Probes actually paid across all tenants (global + local).
    pub probes_paid: usize,
    pub searches_completed: usize,
    pub searches_abandoned: usize,
}

impl TuningRunReport {
    pub fn makespan(&self) -> f64 {
        self.sim.makespan
    }

    pub fn cache_hit_ratio(&self) -> f64 {
        self.multi.cluster_cache_hit_ratio()
    }
}

/// The assembled per-tenant tuning plane.
pub struct TuningPlane {
    /// The identification loop underneath (router shards, shared DB,
    /// consolidated off-line cycle, adaptive cadence).
    pub coord: MultiTenantCoordinator,
    tenants: BTreeMap<TenantId, TenantTuning>,
    explorer: ExplorerConfig,
    max_context_age: f64,
    /// label -> tenant whose search stored the optimum.
    search_owner: BTreeMap<u32, TenantId>,
    /// Cache hits on an optimum some other tenant searched for.
    pub cross_tenant_hits: usize,
    /// Windows observed across all ticks driven by this plane.
    windows_observed: usize,
}

impl TuningPlane {
    pub fn new(config: TuningPlaneConfig) -> TuningPlane {
        let mut coord = MultiTenantCoordinator::new(config.coordinator);
        coord.cadence = config.cadence;
        TuningPlane {
            coord,
            tenants: BTreeMap::new(),
            explorer: config.explorer,
            max_context_age: config.max_context_age,
            search_owner: BTreeMap::new(),
            cross_tenant_hits: 0,
            windows_observed: 0,
        }
    }

    /// Ensure tenant `t` exists: a router shard in the coordinator and
    /// a plug-in wired to that shard's context stream plus the shared
    /// knowledge plane.
    pub fn ensure_tenant(&mut self, t: TenantId) {
        self.coord.ensure_tenant(t);
        if !self.tenants.contains_key(&t) {
            let ctx = self
                .coord
                .router()
                .shard(t)
                .expect("shard just ensured")
                .context
                .clone();
            let mut plugin = KermitPlugin::new(self.coord.db.clone(), ctx);
            plugin.explorer_config = self.explorer.clone();
            plugin.max_context_age = self.max_context_age;
            self.tenants.insert(
                t,
                TenantTuning {
                    plugin,
                    pending: BTreeMap::new(),
                    choices: Vec::new(),
                },
            );
        }
    }

    pub fn n_tenants(&self) -> usize {
        self.tenants.len()
    }

    /// Tenant `t`'s plug-in stats (None before `ensure_tenant`).
    pub fn stats(&self, t: TenantId) -> Option<&PluginStats> {
        self.tenants.get(&t).map(|tt| &tt.plugin.stats)
    }

    /// Tenant `t`'s decision log in request order.
    pub fn choices(&self, t: TenantId) -> Option<&[ChoiceKind]> {
        self.tenants.get(&t).map(|tt| tt.choices.as_slice())
    }

    /// Algorithm 1 for tenant `t` at time `now` (`app_id` keys the
    /// probe-measurement correlation). The plane resolves the label
    /// once, runs the tenant's plug-in, and tracks the cross-tenant
    /// reuse bookkeeping (who paid for which optimum).
    pub fn decide(
        &mut self,
        t: TenantId,
        app_id: u64,
        now: f64,
    ) -> (ConfigIndex, ChoiceKind) {
        self.ensure_tenant(t);
        let tt = self.tenants.get_mut(&t).unwrap();
        let label = tt.plugin.current_label(now);
        let completed_before = tt.plugin.stats.searches_completed;
        let (config, kind) = tt.plugin.choose_config_for_label(label);
        if label != UNKNOWN {
            if tt.plugin.stats.searches_completed > completed_before {
                // this tenant's own search converged on this request
                // and persisted the optimum: it owns the label now —
                // overwrite, because after drift a *different* tenant
                // may have paid the re-search for a label somebody
                // else owned first. (The abandon path deliberately
                // does NOT touch ownership: the optimum it serves was
                // stored by whoever already owns the label.)
                self.search_owner.insert(label, t);
            }
            if kind == ChoiceKind::CacheHit
                && self.search_owner.get(&label).is_some_and(|o| *o != t)
            {
                self.cross_tenant_hits += 1;
            }
            if matches!(
                kind,
                ChoiceKind::GlobalProbe | ChoiceKind::LocalProbe
            ) {
                tt.pending.insert(app_id, label);
            }
        }
        tt.choices.push(kind);
        if tt.choices.len() > CHOICE_LOG_CAP {
            tt.choices.drain(..CHOICE_LOG_CAP / 2);
        }
        (config, kind)
    }

    /// Completion feedback for tenant `t`'s application `app_id`.
    pub fn complete(&mut self, t: TenantId, app_id: u64, duration: f64) {
        if let Some(tt) = self.tenants.get_mut(&t) {
            if let Some(label) = tt.pending.remove(&app_id) {
                tt.plugin.record_measurement(label, duration);
            }
        }
    }

    /// Drive per-tenant job schedules through the shared simcluster
    /// with this plane as the RM plug-in hub: the full closed loop
    /// (monitor → analyze → plan → execute → knowledge) per tenant.
    pub fn run_schedules(
        &mut self,
        schedules: &[(TenantId, Vec<JobSpec>)],
        sim: MultiEngineConfig,
        seed: u64,
    ) -> TuningRunReport {
        let mut engine = MultiClusterEngine::new(
            ResourceManager::default_cluster(),
            sim,
            seed,
        );
        for (t, jobs) in schedules {
            self.ensure_tenant(*t);
            engine.push_jobs(*t, jobs);
        }
        let sim_result = engine.run(self);
        // drain whatever is still pending in the shards
        self.windows_observed += self.coord.tick();
        self.report(sim_result)
    }

    /// Build the aggregate report for a finished run.
    pub fn report(&self, sim: MultiSimResult) -> TuningRunReport {
        let mut multi = self.coord.report(self.windows_observed);
        multi.tenant_stats = self
            .tenants
            .iter()
            .map(|(t, tt)| (*t, tt.plugin.stats.clone()))
            .collect();
        let (probes, completed, abandoned) = multi.tenant_stats.iter().fold(
            (0, 0, 0),
            |(p, c, a), (_, s)| {
                (
                    p + s.probes_paid(),
                    c + s.searches_completed,
                    a + s.searches_abandoned,
                )
            },
        );
        TuningRunReport {
            sim,
            multi,
            cross_tenant_hits: self.cross_tenant_hits,
            probes_paid: probes,
            searches_completed: completed,
            searches_abandoned: abandoned,
        }
    }
}

impl TenantRmPlugin for TuningPlane {
    fn on_samples(&mut self, t: TenantId, samples: &[Sample]) {
        self.coord.ingest(t, samples);
        self.windows_observed += self.coord.tick();
    }

    fn on_resource_request(
        &mut self,
        t: TenantId,
        req: &ResourceRequest,
    ) -> TuningConfig {
        let (config, _kind) = self.decide(t, req.app_id, req.time);
        config.to_config()
    }

    fn on_app_complete(
        &mut self,
        t: TenantId,
        app_id: u64,
        duration: f64,
        _now: f64,
    ) {
        self.complete(t, app_id, duration);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::knowledge::Characterization;
    use crate::online::context::WorkloadContext;
    use crate::simcluster::perfmodel::job_duration;

    fn publish(plane: &TuningPlane, t: TenantId, label: u32, time: f64) {
        let ctx = plane
            .coord
            .router()
            .shard(t)
            .unwrap()
            .context
            .clone();
        ctx.lock().unwrap().publish(WorkloadContext {
            window_index: 0,
            time,
            current_label: label,
            pred_1: label,
            pred_5: label,
            pred_10: label,
        });
    }

    fn insert_workload(plane: &TuningPlane) -> u32 {
        let rows: Vec<Vec<f64>> = vec![vec![1.0; 4], vec![1.1; 4]];
        plane.coord.db.write().unwrap().insert_new(
            Characterization::from_vec_rows(&rows),
            vec![1.05; 4],
            2,
            false,
        )
    }

    #[test]
    fn late_joining_tenant_cache_hits_with_zero_probes() {
        // satellite pin, at K=4: tenant A pays the global search; a
        // late-joining tenant B with the same workload label gets
        // CacheHit on its FIRST in-sync request — zero probes paid by B
        // (and the remaining tenants reuse the same optimum too)
        let mut plane = TuningPlane::new(TuningPlaneConfig::default());
        let (a, b) = (TenantId(0), TenantId(1));
        plane.ensure_tenant(a);
        let label = insert_workload(&plane);
        publish(&plane, a, label, 0.0);

        // drive A's search to convergence (app ids arbitrary but unique)
        let mut app = 0u64;
        loop {
            let (c, kind) = plane.decide(a, app, 1.0);
            match kind {
                ChoiceKind::GlobalProbe => {
                    plane.complete(a, app, job_duration(2, &c.to_config()));
                }
                ChoiceKind::CacheHit => break,
                other => panic!("unexpected {other:?}"),
            }
            app += 1;
        }
        let a_stats = plane.stats(a).unwrap().clone();
        assert!(a_stats.probes_paid() > 5, "{a_stats:?}");
        assert_eq!(a_stats.searches_completed, 1);
        // A's own hit is not cross-tenant
        assert_eq!(plane.cross_tenant_hits, 0);

        // B joins late, sees the same workload label in its context
        plane.ensure_tenant(b);
        publish(&plane, b, label, 2.0);
        let (cfg_b, kind_b) = plane.decide(b, 999, 2.5);
        assert_eq!(kind_b, ChoiceKind::CacheHit, "B's first request");
        let stored = plane
            .coord
            .db
            .read()
            .unwrap()
            .get(label)
            .unwrap()
            .config
            .unwrap();
        assert_eq!(cfg_b, stored);
        let b_stats = plane.stats(b).unwrap();
        assert_eq!(b_stats.probes_paid(), 0, "B paid probes: {b_stats:?}");
        assert_eq!(b_stats.defaults, 0);
        assert_eq!(plane.cross_tenant_hits, 1);
        assert_eq!(plane.choices(b).unwrap(), &[ChoiceKind::CacheHit]);

        // two more late joiners: K=4 tenants total, one search paid
        for (k, t) in [TenantId(2), TenantId(3)].into_iter().enumerate() {
            plane.ensure_tenant(t);
            publish(&plane, t, label, 3.0);
            let (cfg, kind) = plane.decide(t, 1000 + k as u64, 3.5);
            assert_eq!(kind, ChoiceKind::CacheHit, "{t}");
            assert_eq!(cfg, stored, "{t}");
            assert_eq!(plane.stats(t).unwrap().probes_paid(), 0, "{t}");
        }
        assert_eq!(plane.n_tenants(), 4);
        assert_eq!(plane.cross_tenant_hits, 3);
        let report = plane.report(MultiSimResult::default());
        assert_eq!(report.multi.tenant_stats.len(), 4);
        // cluster-wide: A's probes dilute the ratio, the three reusing
        // tenants are pure cache hits
        assert!(report.cache_hit_ratio() > 0.0);
        assert_eq!(report.searches_completed, 1);
    }

    #[test]
    fn stale_context_falls_back_to_default_per_tenant() {
        // satellite pin: per-tenant staleness — tenant A in sync keeps
        // its real decision path while tenant B's stale context maps to
        // ChoiceKind::Default, visible per tenant in the report stats
        let mut plane = TuningPlane::new(TuningPlaneConfig::default());
        let (a, b) = (TenantId(0), TenantId(1));
        plane.ensure_tenant(a);
        plane.ensure_tenant(b);
        let label = insert_workload(&plane);
        publish(&plane, a, label, 1000.0);
        publish(&plane, b, label, 0.0); // will be stale at t=1000

        let (_, kind_a) = plane.decide(a, 0, 1000.0);
        assert_eq!(kind_a, ChoiceKind::GlobalProbe);
        plane.complete(a, 0, 100.0);
        let (cfg_b, kind_b) = plane.decide(b, 1, 1000.0);
        assert_eq!(kind_b, ChoiceKind::Default);
        assert_eq!(
            cfg_b,
            crate::simcluster::default_config_index()
        );

        let report = plane.report(MultiSimResult::default());
        let stats: BTreeMap<TenantId, PluginStats> =
            report.multi.tenant_stats.iter().cloned().collect();
        assert_eq!(stats[&a].defaults, 0);
        assert_eq!(stats[&a].global_probes, 1);
        assert_eq!(stats[&b].defaults, 1);
        assert_eq!(stats[&b].probes_paid(), 0);
    }

    #[test]
    fn concurrent_searchers_dedup_through_the_shared_plane() {
        // A and B both start searching the same label; A converges
        // first; B's next request abandons its session and cache-hits —
        // counted as a cross-tenant hit
        let mut plane = TuningPlane::new(TuningPlaneConfig::default());
        let (a, b) = (TenantId(0), TenantId(1));
        plane.ensure_tenant(a);
        plane.ensure_tenant(b);
        let label = insert_workload(&plane);
        publish(&plane, a, label, 0.0);
        publish(&plane, b, label, 0.0);

        // B probes once, then stalls (its jobs are long)
        let (cb, kb) = plane.decide(b, 1000, 1.0);
        assert_eq!(kb, ChoiceKind::GlobalProbe);
        plane.complete(b, 1000, job_duration(2, &cb.to_config()));

        // A searches to convergence
        let mut app = 0u64;
        loop {
            let (c, kind) = plane.decide(a, app, 1.0);
            match kind {
                ChoiceKind::GlobalProbe => {
                    plane.complete(a, app, job_duration(2, &c.to_config()))
                }
                ChoiceKind::CacheHit => break,
                other => panic!("unexpected {other:?}"),
            }
            app += 1;
        }

        // B's next request: session abandoned, A's optimum served
        let before = plane.stats(b).unwrap().probes_paid();
        let (_, kb2) = plane.decide(b, 2000, 2.0);
        assert_eq!(kb2, ChoiceKind::CacheHit);
        let b_stats = plane.stats(b).unwrap();
        assert_eq!(b_stats.searches_abandoned, 1);
        assert_eq!(b_stats.probes_paid(), before);
        assert!(plane.cross_tenant_hits >= 1);
    }

    #[test]
    fn unknown_label_never_creates_pending_entries() {
        let mut plane = TuningPlane::new(TuningPlaneConfig::default());
        let t = TenantId(0);
        plane.ensure_tenant(t);
        // no context published at all
        let (_, kind) = plane.decide(t, 0, 0.0);
        assert_eq!(kind, ChoiceKind::Default);
        // completion for an app with no pending probe is a no-op
        plane.complete(t, 0, 50.0);
        assert_eq!(plane.stats(t).unwrap().defaults, 1);
    }
}
