//! Multinomial logistic regression comparator (Fig 6): softmax + SGD on
//! standardised features, with L2 regularisation. Weights and the
//! standardised design matrix live in contiguous `Matrix` storage.

use super::dataset::Dataset;
use super::Classifier;
use crate::linalg::Matrix;
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct LogRegConfig {
    pub epochs: usize,
    pub lr: f64,
    pub l2: f64,
    pub batch: usize,
}

impl Default for LogRegConfig {
    fn default() -> Self {
        LogRegConfig { epochs: 60, lr: 0.1, l2: 1e-4, batch: 32 }
    }
}

#[derive(Debug, Clone)]
pub struct LogReg {
    classes: Vec<u32>,
    /// One row per class: the class weights, plus bias at index `width`.
    weights: Matrix,
    moments: Vec<(f64, f64)>,
}

impl LogReg {
    pub fn fit(data: &Dataset, config: LogRegConfig, rng: &mut Rng) -> LogReg {
        assert!(!data.is_empty());
        let classes = data.classes();
        let w = data.width();
        let moments = data.feature_moments();
        let mut rows = Matrix::zeros(data.len(), w);
        for i in 0..data.len() {
            for (j, &v) in data.row(i).iter().enumerate() {
                rows.row_mut(i)[j] = (v - moments[j].0) / moments[j].1;
            }
        }
        let class_index: std::collections::BTreeMap<u32, usize> =
            classes.iter().enumerate().map(|(i, &c)| (c, i)).collect();
        let d = w + 1; // + bias column
        let mut weights = Matrix::zeros(classes.len(), d);
        let mut grad = vec![0.0f64; classes.len() * d];

        let mut order: Vec<usize> = (0..rows.n_rows()).collect();
        for _ in 0..config.epochs {
            rng.shuffle(&mut order);
            for chunk in order.chunks(config.batch) {
                // accumulate gradient over the minibatch
                grad.fill(0.0);
                for &i in chunk {
                    let x = rows.row(i);
                    let probs = softmax_scores(&weights, x);
                    let yi = class_index[&data.labels[i]];
                    for (c, p) in probs.iter().enumerate() {
                        let err = p - if c == yi { 1.0 } else { 0.0 };
                        let g = &mut grad[c * d..(c + 1) * d];
                        for j in 0..w {
                            g[j] += err * x[j];
                        }
                        g[w] += err;
                    }
                }
                let scale = config.lr / chunk.len() as f64;
                for c in 0..classes.len() {
                    let ws = weights.row_mut(c);
                    let g = &grad[c * d..(c + 1) * d];
                    for j in 0..d {
                        ws[j] -= scale
                            * (g[j] + config.l2 * ws[j] * chunk.len() as f64);
                    }
                }
            }
        }
        LogReg { classes, weights, moments }
    }

    fn scores(&self, x: &[f64]) -> Vec<f64> {
        let xs: Vec<f64> = x
            .iter()
            .zip(&self.moments)
            .map(|(v, (m, s))| (v - m) / s)
            .collect();
        softmax_scores(&self.weights, &xs)
    }
}

fn softmax_scores(weights: &Matrix, x: &[f64]) -> Vec<f64> {
    let w = x.len();
    let logits: Vec<f64> = weights
        .iter_rows()
        .map(|ws| {
            ws[..w].iter().zip(x).map(|(a, b)| a * b).sum::<f64>() + ws[w]
        })
        .collect();
    let max = logits.iter().copied().fold(f64::NEG_INFINITY, f64::max);
    let exps: Vec<f64> = logits.iter().map(|&l| (l - max).exp()).collect();
    let z: f64 = exps.iter().sum();
    exps.into_iter().map(|e| e / z).collect()
}

impl Classifier for LogReg {
    fn predict(&self, x: &[f64]) -> u32 {
        let s = self.scores(x);
        let best = s
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        self.classes[best]
    }

    fn predict_proba(&self, x: &[f64]) -> Option<Vec<(u32, f64)>> {
        Some(
            self.classes
                .iter()
                .copied()
                .zip(self.scores(x))
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ml::metrics::accuracy;

    #[test]
    fn learns_linear_boundary() {
        let mut rng = Rng::new(0);
        let mut d = Dataset::new();
        for _ in 0..200 {
            let x = rng.normal_ms(0.0, 2.0);
            let y = rng.normal_ms(0.0, 2.0);
            d.push(vec![x, y], if x + y > 0.0 { 1 } else { 0 });
        }
        let (tr, te) = d.split(&mut rng, 0.25);
        let m = LogReg::fit(&tr, LogRegConfig::default(), &mut rng);
        let acc = accuracy(&te.labels, &m.predict_batch(te.x()));
        assert!(acc > 0.92, "{acc}");
    }

    #[test]
    fn three_class_separation() {
        let mut rng = Rng::new(1);
        let mut d = Dataset::new();
        let centers = [(0.0, 0.0), (6.0, 0.0), (0.0, 6.0)];
        for _ in 0..150 {
            for (c, (cx, cy)) in centers.iter().enumerate() {
                d.push(
                    vec![rng.normal_ms(*cx, 1.0), rng.normal_ms(*cy, 1.0)],
                    c as u32,
                );
            }
        }
        let (tr, te) = d.split(&mut rng, 0.25);
        let m = LogReg::fit(&tr, LogRegConfig::default(), &mut rng);
        let acc = accuracy(&te.labels, &m.predict_batch(te.x()));
        assert!(acc > 0.95, "{acc}");
    }

    #[test]
    fn proba_is_distribution() {
        let mut rng = Rng::new(2);
        let mut d = Dataset::new();
        d.push(vec![0.0], 0);
        d.push(vec![1.0], 1);
        d.push(vec![0.2], 0);
        d.push(vec![0.8], 1);
        let m = LogReg::fit(&d, LogRegConfig::default(), &mut rng);
        let p = m.predict_proba(&[0.5]).unwrap();
        let sum: f64 = p.iter().map(|(_, q)| q).sum();
        assert!((sum - 1.0).abs() < 1e-9);
    }
}
