//! Ordinary least squares linear regression (normal equations with ridge
//! fallback). This is the *baseline* predictor the paper criticises
//! (§3: "Linear regression models typically used to predict workload
//! characteristics perform poorly with abrupt workload transitions") —
//! benchmarked against the LSTM WorkloadPredictor in
//! `benches/predictor_accuracy.rs`.

/// Fitted linear model y = w.x + b.
#[derive(Debug, Clone)]
pub struct LinReg {
    pub weights: Vec<f64>,
    pub bias: f64,
}

impl LinReg {
    /// Least squares fit via the normal equations (X^T X + λI) w = X^T y.
    /// A small ridge term keeps the Cholesky solve stable when features
    /// are collinear.
    pub fn fit(xs: &[Vec<f64>], ys: &[f64], ridge: f64) -> LinReg {
        assert_eq!(xs.len(), ys.len());
        assert!(!xs.is_empty());
        let w = xs[0].len();
        let d = w + 1; // + bias column
        // build X^T X and X^T y with the implicit 1s column
        let mut xtx = vec![vec![0.0; d]; d];
        let mut xty = vec![0.0; d];
        for (x, &y) in xs.iter().zip(ys) {
            for i in 0..w {
                for j in i..w {
                    xtx[i][j] += x[i] * x[j];
                }
                xtx[i][w] += x[i];
                xty[i] += x[i] * y;
            }
            xtx[w][w] += 1.0;
            xty[w] += y;
        }
        for i in 0..d {
            for j in 0..i {
                xtx[i][j] = xtx[j][i];
            }
            xtx[i][i] += ridge;
        }
        let sol = solve_cholesky(&mut xtx, &xty)
            .expect("normal equations not PD even with ridge");
        LinReg { weights: sol[..w].to_vec(), bias: sol[w] }
    }

    pub fn predict(&self, x: &[f64]) -> f64 {
        self.weights.iter().zip(x).map(|(a, b)| a * b).sum::<f64>()
            + self.bias
    }
}

/// Cholesky solve of A x = b for symmetric positive-definite A
/// (A is overwritten with its factor).
fn solve_cholesky(a: &mut [Vec<f64>], b: &[f64]) -> Option<Vec<f64>> {
    let n = b.len();
    // factor: A = L L^T stored in lower triangle
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a[i][j];
            for k in 0..j {
                sum -= a[i][k] * a[j][k];
            }
            if i == j {
                if sum <= 0.0 {
                    return None;
                }
                a[i][i] = sum.sqrt();
            } else {
                a[i][j] = sum / a[j][j];
            }
        }
    }
    // forward: L y = b
    let mut y = vec![0.0; n];
    for i in 0..n {
        let mut sum = b[i];
        for k in 0..i {
            sum -= a[i][k] * y[k];
        }
        y[i] = sum / a[i][i];
    }
    // back: L^T x = y
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut sum = y[i];
        for k in (i + 1)..n {
            sum -= a[k][i] * x[k];
        }
        x[i] = sum / a[i][i];
    }
    Some(x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn recovers_exact_linear_function() {
        // y = 2x0 - 3x1 + 5
        let xs: Vec<Vec<f64>> = (0..20)
            .map(|i| vec![i as f64, (i * i % 7) as f64])
            .collect();
        let ys: Vec<f64> =
            xs.iter().map(|x| 2.0 * x[0] - 3.0 * x[1] + 5.0).collect();
        let m = LinReg::fit(&xs, &ys, 1e-9);
        assert!((m.weights[0] - 2.0).abs() < 1e-6);
        assert!((m.weights[1] + 3.0).abs() < 1e-6);
        assert!((m.bias - 5.0).abs() < 1e-5);
    }

    #[test]
    fn noisy_fit_close() {
        let mut rng = Rng::new(0);
        let xs: Vec<Vec<f64>> =
            (0..500).map(|_| vec![rng.normal(), rng.normal()]).collect();
        let ys: Vec<f64> = xs
            .iter()
            .map(|x| 1.5 * x[0] + 0.5 * x[1] - 2.0 + rng.normal() * 0.1)
            .collect();
        let m = LinReg::fit(&xs, &ys, 1e-6);
        assert!((m.weights[0] - 1.5).abs() < 0.05);
        assert!((m.weights[1] - 0.5).abs() < 0.05);
        assert!((m.bias + 2.0).abs() < 0.05);
    }

    #[test]
    fn collinear_features_survive_via_ridge() {
        let xs: Vec<Vec<f64>> =
            (0..10).map(|i| vec![i as f64, 2.0 * i as f64]).collect();
        let ys: Vec<f64> = xs.iter().map(|x| x[0]).collect();
        let m = LinReg::fit(&xs, &ys, 1e-6);
        // prediction should still be right even if weights are split
        for (x, &y) in xs.iter().zip(&ys) {
            assert!((m.predict(x) - y).abs() < 1e-3);
        }
    }

    #[test]
    fn constant_target() {
        let xs: Vec<Vec<f64>> = (0..5).map(|i| vec![i as f64]).collect();
        let ys = vec![7.0; 5];
        let m = LinReg::fit(&xs, &ys, 1e-9);
        assert!((m.predict(&[100.0]) - 7.0).abs() < 1e-6);
    }
}
