//! Random forest — the paper's WorkloadClassifier and TransitionClassifier
//! algorithm (§7.2). Bagged CART trees with per-split feature subsetting,
//! majority vote, and soft voting for predict_proba.

use super::dataset::Dataset;
use super::tree::{DecisionTree, TreeConfig};
use super::Classifier;
use crate::linalg::engine::Engine;
use crate::util::rng::Rng;
use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct ForestConfig {
    pub n_trees: usize,
    pub max_depth: usize,
    pub min_samples_split: usize,
    /// Features per split; None = sqrt(width) (the standard default).
    pub mtry: Option<usize>,
    /// Bootstrap sample size as a fraction of the training set.
    pub sample_frac: f64,
}

impl Default for ForestConfig {
    fn default() -> Self {
        ForestConfig {
            n_trees: 60,
            max_depth: 20,
            min_samples_split: 2,
            mtry: None,
            sample_frac: 1.0,
        }
    }
}

#[derive(Debug, Clone)]
pub struct RandomForest {
    pub trees: Vec<DecisionTree>,
}

impl RandomForest {
    pub fn fit(data: &Dataset, config: ForestConfig, rng: &mut Rng) -> RandomForest {
        Self::fit_with(data, config, rng, Engine::sequential())
    }

    /// Engine-parallel [`RandomForest::fit`]: the per-tree RNG streams
    /// are forked from `rng` sequentially (same draw order as the
    /// sequential path), then bootstrap + CART fitting fan out over the
    /// engine's persistent worker pool — each tree owns its forked
    /// stream, so the
    /// forest is bit-identical to the sequential fit for any thread
    /// count. Trees are heavy work items, so parallelism engages from
    /// two trees up regardless of the engine's row-loop threshold.
    pub fn fit_with(
        data: &Dataset,
        config: ForestConfig,
        rng: &mut Rng,
        engine: Engine,
    ) -> RandomForest {
        assert!(!data.is_empty());
        let mtry = config
            .mtry
            .unwrap_or_else(|| (data.width() as f64).sqrt().ceil() as usize)
            .max(1);
        let n_boot =
            ((data.len() as f64) * config.sample_frac).round().max(1.0) as usize;
        let tree_cfg = TreeConfig {
            max_depth: config.max_depth,
            min_samples_split: config.min_samples_split,
            mtry: Some(mtry),
        };
        let mut slots: Vec<(Rng, Option<DecisionTree>)> = (0..config.n_trees)
            .map(|k| (rng.fork(k as u64), None))
            .collect();
        engine.with_min_items(2).for_rows(&mut slots, 1, |_, chunk| {
            for (trng, slot) in chunk.iter_mut() {
                let boot = data.bootstrap(trng, n_boot);
                *slot = Some(DecisionTree::fit(&boot, tree_cfg.clone(), trng));
            }
        });
        let trees = slots.into_iter().map(|(_, t)| t.unwrap()).collect();
        RandomForest { trees }
    }

    /// Hard majority vote: (winning label, vote share). ~2.6x faster
    /// than the soft vote (§Perf iteration 2) — each tree contributes
    /// its leaf majority instead of a per-class probability map — and
    /// agrees with the soft vote on in-distribution data. This is the
    /// on-line hot path, so the tally lives in a stack scratch table
    /// (distinct labels are bounded by the tree count) and the steady
    /// path performs zero heap allocations; the heap spill only engages
    /// for forests voting for more than `STACK_CLASSES` distinct labels.
    /// `vote`/`predict_proba` remain for callers that need the full
    /// distribution.
    pub fn vote_hard(&self, x: &[f64]) -> (u32, f64) {
        const STACK_CLASSES: usize = 64;
        let mut keys = [0u32; STACK_CLASSES];
        let mut counts = [0u32; STACK_CLASSES];
        let mut used = 0usize;
        let mut spill: Vec<(u32, u32)> = Vec::new(); // no alloc until push
        for t in &self.trees {
            let l = t.predict(x);
            if let Some(k) = keys[..used].iter().position(|&k| k == l) {
                counts[k] += 1;
            } else if used < STACK_CLASSES {
                keys[used] = l;
                counts[used] = 1;
                used += 1;
            } else if let Some(e) = spill.iter_mut().find(|e| e.0 == l) {
                e.1 += 1;
            } else {
                spill.push((l, 1));
            }
        }
        assert!(used > 0, "empty forest");
        // winner: highest count; ties go to the largest label (the
        // behaviour of the previous BTreeMap + max_by_key tally)
        let mut best_label = keys[0];
        let mut best_n = counts[0];
        for k in 1..used {
            if counts[k] > best_n
                || (counts[k] == best_n && keys[k] > best_label)
            {
                best_label = keys[k];
                best_n = counts[k];
            }
        }
        for &(l, n) in &spill {
            if n > best_n || (n == best_n && l > best_label) {
                best_label = l;
                best_n = n;
            }
        }
        (best_label, best_n as f64 / self.trees.len() as f64)
    }

    /// Soft-vote class distribution.
    pub fn vote(&self, x: &[f64]) -> BTreeMap<u32, f64> {
        let mut votes: BTreeMap<u32, f64> = BTreeMap::new();
        for t in &self.trees {
            if let Some(p) = t.predict_proba(x) {
                for (c, q) in p {
                    *votes.entry(c).or_insert(0.0) += q;
                }
            }
        }
        let total: f64 = votes.values().sum();
        if total > 0.0 {
            for v in votes.values_mut() {
                *v /= total;
            }
        }
        votes
    }
}

impl Classifier for RandomForest {
    fn predict(&self, x: &[f64]) -> u32 {
        self.vote(x)
            .into_iter()
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .map(|(c, _)| c)
            .expect("empty forest")
    }

    fn predict_proba(&self, x: &[f64]) -> Option<Vec<(u32, f64)>> {
        Some(self.vote(x).into_iter().collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ml::metrics::accuracy;

    fn gaussian_blobs(n: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        let centers = [
            vec![0.0, 0.0, 0.0],
            vec![5.0, 0.0, 2.0],
            vec![0.0, 5.0, -2.0],
            vec![5.0, 5.0, 0.0],
        ];
        let mut d = Dataset::new();
        for _ in 0..n {
            let c = rng.range_usize(0, centers.len());
            let row: Vec<f64> = centers[c]
                .iter()
                .map(|&m| rng.normal_ms(m, 0.8))
                .collect();
            d.push(row, c as u32);
        }
        d
    }

    #[test]
    fn beats_90_percent_on_blobs() {
        let d = gaussian_blobs(400, 0);
        let mut rng = Rng::new(1);
        let (tr, te) = d.split(&mut rng, 0.25);
        let f = RandomForest::fit(&tr, ForestConfig::default(), &mut rng);
        let preds = f.predict_batch(te.x());
        let acc = accuracy(&te.labels, &preds);
        assert!(acc > 0.9, "{acc}");
    }

    #[test]
    fn deterministic_given_seed() {
        let d = gaussian_blobs(100, 2);
        let mk = |seed| {
            let mut rng = Rng::new(seed);
            let f = RandomForest::fit(
                &d,
                ForestConfig { n_trees: 10, ..Default::default() },
                &mut rng,
            );
            f.predict_batch(d.x())
        };
        assert_eq!(mk(5), mk(5));
    }

    #[test]
    fn proba_is_distribution() {
        let d = gaussian_blobs(100, 3);
        let mut rng = Rng::new(4);
        let f = RandomForest::fit(
            &d,
            ForestConfig { n_trees: 15, ..Default::default() },
            &mut rng,
        );
        let p = f.predict_proba(d.row(0)).unwrap();
        let sum: f64 = p.iter().map(|(_, q)| q).sum();
        assert!((sum - 1.0).abs() < 1e-9);
        assert!(p.iter().all(|&(_, q)| (0.0..=1.0).contains(&q)));
    }

    #[test]
    fn parallel_fit_and_predict_match_sequential() {
        let d = gaussian_blobs(300, 7);
        let cfg = ForestConfig { n_trees: 12, ..Default::default() };
        let mut ra = Rng::new(8);
        let a = RandomForest::fit(&d, cfg.clone(), &mut ra);
        let seq_preds = a.predict_batch(d.x());
        for threads in [2, 4] {
            let engine = Engine::with_threads(threads);
            let mut rb = Rng::new(8);
            let b = RandomForest::fit_with(&d, cfg.clone(), &mut rb, engine);
            assert_eq!(seq_preds, b.predict_batch(d.x()), "fit diverged at {threads} threads");
            assert_eq!(
                seq_preds,
                a.predict_batch_with(engine.with_min_items(1), d.x()),
                "batch predict diverged at {threads} threads"
            );
        }
    }

    #[test]
    fn single_class_dataset() {
        let mut d = Dataset::new();
        for i in 0..20 {
            d.push(vec![i as f64], 3);
        }
        let mut rng = Rng::new(6);
        let f = RandomForest::fit(
            &d,
            ForestConfig { n_trees: 5, ..Default::default() },
            &mut rng,
        );
        assert_eq!(f.predict(&[100.0]), 3);
    }
}
