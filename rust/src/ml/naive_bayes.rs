//! Gaussian naive Bayes comparator (Fig 6).

use super::dataset::Dataset;
use super::Classifier;
use std::collections::BTreeMap;

#[derive(Debug, Clone)]
struct ClassModel {
    prior_ln: f64,
    mean: Vec<f64>,
    var: Vec<f64>, // smoothed
}

#[derive(Debug, Clone)]
pub struct GaussianNb {
    classes: BTreeMap<u32, ClassModel>,
}

impl GaussianNb {
    pub fn fit(data: &Dataset) -> GaussianNb {
        assert!(!data.is_empty());
        let w = data.width();
        let n = data.len() as f64;
        // global variance floor (sklearn-style epsilon smoothing)
        let moments = data.feature_moments();
        let eps: f64 = 1e-9
            * moments.iter().map(|(_, s)| s * s).fold(0.0_f64, f64::max).max(1e-9);

        let mut classes = BTreeMap::new();
        for c in data.classes() {
            let idx: Vec<usize> = (0..data.len())
                .filter(|&i| data.labels[i] == c)
                .collect();
            let nc = idx.len() as f64;
            let mut mean = vec![0.0; w];
            let mut var = vec![0.0; w];
            for &i in &idx {
                for j in 0..w {
                    mean[j] += data.row(i)[j];
                }
            }
            for m in mean.iter_mut() {
                *m /= nc;
            }
            for &i in &idx {
                for j in 0..w {
                    let d = data.row(i)[j] - mean[j];
                    var[j] += d * d;
                }
            }
            for v in var.iter_mut() {
                *v = *v / nc + eps;
            }
            classes.insert(
                c,
                ClassModel { prior_ln: (nc / n).ln(), mean, var },
            );
        }
        GaussianNb { classes }
    }

    fn log_joint(&self, x: &[f64]) -> Vec<(u32, f64)> {
        self.classes
            .iter()
            .map(|(&c, m)| {
                let mut lj = m.prior_ln;
                for j in 0..x.len() {
                    let d = x[j] - m.mean[j];
                    lj += -0.5
                        * ((2.0 * std::f64::consts::PI * m.var[j]).ln()
                            + d * d / m.var[j]);
                }
                (c, lj)
            })
            .collect()
    }
}

impl Classifier for GaussianNb {
    fn predict(&self, x: &[f64]) -> u32 {
        self.log_joint(x)
            .into_iter()
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .map(|(c, _)| c)
            .unwrap()
    }

    fn predict_proba(&self, x: &[f64]) -> Option<Vec<(u32, f64)>> {
        let lj = self.log_joint(x);
        let max = lj.iter().map(|&(_, v)| v).fold(f64::NEG_INFINITY, f64::max);
        let exps: Vec<(u32, f64)> =
            lj.into_iter().map(|(c, v)| (c, (v - max).exp())).collect();
        let z: f64 = exps.iter().map(|&(_, e)| e).sum();
        Some(exps.into_iter().map(|(c, e)| (c, e / z)).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ml::metrics::accuracy;
    use crate::util::rng::Rng;

    #[test]
    fn separates_gaussians() {
        let mut rng = Rng::new(0);
        let mut d = Dataset::new();
        for _ in 0..200 {
            d.push(vec![rng.normal_ms(0.0, 1.0), rng.normal_ms(0.0, 1.0)], 0);
            d.push(vec![rng.normal_ms(5.0, 1.0), rng.normal_ms(-3.0, 1.0)], 1);
        }
        let (tr, te) = d.split(&mut rng, 0.25);
        let nb = GaussianNb::fit(&tr);
        let acc = accuracy(&te.labels, &nb.predict_batch(te.x()));
        assert!(acc > 0.97, "{acc}");
    }

    #[test]
    fn respects_priors_under_imbalance() {
        let mut rng = Rng::new(1);
        let mut d = Dataset::new();
        // 95:5 imbalance, fully overlapping features
        for _ in 0..190 {
            d.push(vec![rng.normal()], 0);
        }
        for _ in 0..10 {
            d.push(vec![rng.normal()], 1);
        }
        let nb = GaussianNb::fit(&d);
        // ambiguous point -> majority class wins via prior
        assert_eq!(nb.predict(&[0.0]), 0);
    }

    #[test]
    fn proba_normalised() {
        let mut d = Dataset::new();
        d.push(vec![0.0], 0);
        d.push(vec![1.0], 1);
        d.push(vec![0.1], 0);
        d.push(vec![0.9], 1);
        let nb = GaussianNb::fit(&d);
        let p = nb.predict_proba(&[0.5]).unwrap();
        let sum: f64 = p.iter().map(|(_, q)| q).sum();
        assert!((sum - 1.0).abs() < 1e-9);
    }

    #[test]
    fn constant_feature_does_not_nan() {
        let mut d = Dataset::new();
        d.push(vec![1.0, 5.0], 0);
        d.push(vec![1.0, 6.0], 1);
        d.push(vec![1.0, 5.1], 0);
        d.push(vec![1.0, 6.1], 1);
        let nb = GaussianNb::fit(&d);
        let p = nb.predict(&[1.0, 5.05]);
        assert_eq!(p, 0);
    }
}
