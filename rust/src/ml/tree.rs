//! CART decision tree (gini impurity, axis-aligned splits).
//!
//! Building block for the random forest (paper's WorkloadClassifier /
//! TransitionClassifier) and the standalone DecisionTree comparator in
//! Fig 6. Supports per-split random feature subsetting (mtry) for the
//! forest's decorrelation.

use super::dataset::Dataset;
use super::Classifier;
use crate::util::rng::Rng;
use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub enum Node {
    Leaf {
        /// Class-count distribution at the leaf (kept for predict_proba).
        counts: BTreeMap<u32, usize>,
        majority: u32,
    },
    Split {
        feature: usize,
        threshold: f64,
        left: Box<Node>,  // x[feature] <= threshold
        right: Box<Node>, // x[feature] >  threshold
    },
}

#[derive(Debug, Clone)]
pub struct TreeConfig {
    pub max_depth: usize,
    pub min_samples_split: usize,
    /// Features to consider per split; None = all (plain CART).
    pub mtry: Option<usize>,
}

impl Default for TreeConfig {
    fn default() -> Self {
        TreeConfig { max_depth: 24, min_samples_split: 2, mtry: None }
    }
}

#[derive(Debug, Clone)]
pub struct DecisionTree {
    pub root: Node,
    pub config: TreeConfig,
}

fn class_counts(labels: &[u32], idx: &[usize]) -> BTreeMap<u32, usize> {
    let mut c = BTreeMap::new();
    for &i in idx {
        *c.entry(labels[i]).or_insert(0) += 1;
    }
    c
}

fn gini(counts: &BTreeMap<u32, usize>, total: usize) -> f64 {
    if total == 0 {
        return 0.0;
    }
    let t = total as f64;
    1.0 - counts
        .values()
        .map(|&n| {
            let p = n as f64 / t;
            p * p
        })
        .sum::<f64>()
}

fn majority(counts: &BTreeMap<u32, usize>) -> u32 {
    counts
        .iter()
        .max_by_key(|(_, &n)| n)
        .map(|(&c, _)| c)
        .expect("majority of empty counts")
}

impl DecisionTree {
    pub fn fit(data: &Dataset, config: TreeConfig, rng: &mut Rng) -> DecisionTree {
        assert!(!data.is_empty(), "fit on empty dataset");
        let idx: Vec<usize> = (0..data.len()).collect();
        let root = Self::build(data, &idx, &config, rng, 0);
        DecisionTree { root, config }
    }

    fn build(
        data: &Dataset,
        idx: &[usize],
        config: &TreeConfig,
        rng: &mut Rng,
        depth: usize,
    ) -> Node {
        let counts = class_counts(&data.labels, idx);
        let node_gini = gini(&counts, idx.len());
        if depth >= config.max_depth
            || idx.len() < config.min_samples_split
            || node_gini == 0.0
        {
            return Node::Leaf { majority: majority(&counts), counts };
        }

        let width = data.width();
        let features: Vec<usize> = match config.mtry {
            Some(k) if k < width => rng.sample_indices(width, k),
            _ => (0..width).collect(),
        };

        let mut best: Option<(usize, f64, f64)> = None; // (feat, thr, score)
        for &f in &features {
            // sort index by feature value; scan split points
            let mut order: Vec<usize> = idx.to_vec();
            order.sort_by(|&a, &b| {
                data.row(a)[f]
                    .partial_cmp(&data.row(b)[f])
                    .unwrap_or(std::cmp::Ordering::Equal)
            });
            let mut left_counts: BTreeMap<u32, usize> = BTreeMap::new();
            let total = order.len();
            for (pos, &i) in order.iter().enumerate().take(total - 1) {
                *left_counts.entry(data.labels[i]).or_insert(0) += 1;
                let v = data.row(i)[f];
                let v_next = data.row(order[pos + 1])[f];
                if v == v_next {
                    continue; // can't split between equal values
                }
                let n_left = pos + 1;
                let n_right = total - n_left;
                // right counts = counts - left_counts
                let mut right_counts = counts.clone();
                for (c, n) in &left_counts {
                    let e = right_counts.get_mut(c).unwrap();
                    *e -= n;
                }
                let score = (n_left as f64) * gini(&left_counts, n_left)
                    + (n_right as f64) * gini(&right_counts, n_right);
                if best.map(|(_, _, s)| score < s).unwrap_or(true) {
                    best = Some((f, 0.5 * (v + v_next), score));
                }
            }
        }

        let (feature, threshold, score) = match best {
            Some(b) => b,
            None => {
                return Node::Leaf { majority: majority(&counts), counts }
            }
        };
        // no impurity improvement -> leaf
        if score / idx.len() as f64 >= node_gini - 1e-12 {
            return Node::Leaf { majority: majority(&counts), counts };
        }

        let (left_idx, right_idx): (Vec<usize>, Vec<usize>) = idx
            .iter()
            .partition(|&&i| data.row(i)[feature] <= threshold);
        assert!(!left_idx.is_empty() && !right_idx.is_empty());
        Node::Split {
            feature,
            threshold,
            left: Box::new(Self::build(data, &left_idx, config, rng, depth + 1)),
            right: Box::new(Self::build(
                data, &right_idx, config, rng, depth + 1,
            )),
        }
    }

    fn leaf_for(&self, x: &[f64]) -> &Node {
        let mut node = &self.root;
        loop {
            match node {
                Node::Leaf { .. } => return node,
                Node::Split { feature, threshold, left, right } => {
                    node = if x[*feature] <= *threshold { left } else { right };
                }
            }
        }
    }

    pub fn depth(&self) -> usize {
        fn d(n: &Node) -> usize {
            match n {
                Node::Leaf { .. } => 0,
                Node::Split { left, right, .. } => 1 + d(left).max(d(right)),
            }
        }
        d(&self.root)
    }
}

impl Classifier for DecisionTree {
    fn predict(&self, x: &[f64]) -> u32 {
        match self.leaf_for(x) {
            Node::Leaf { majority, .. } => *majority,
            _ => unreachable!(),
        }
    }

    fn predict_proba(&self, x: &[f64]) -> Option<Vec<(u32, f64)>> {
        match self.leaf_for(x) {
            Node::Leaf { counts, .. } => {
                let total: usize = counts.values().sum();
                Some(
                    counts
                        .iter()
                        .map(|(&c, &n)| (c, n as f64 / total as f64))
                        .collect(),
                )
            }
            _ => unreachable!(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn xor_dataset() -> Dataset {
        // 2D XOR with jitter — linearly inseparable, trivially tree-separable
        let mut d = Dataset::new();
        let mut rng = Rng::new(0);
        for _ in 0..50 {
            for (a, b) in [(0.0, 0.0), (0.0, 1.0), (1.0, 0.0), (1.0, 1.0)] {
                let label = ((a as u32) ^ (b as u32)) as u32;
                d.push(
                    vec![a + rng.normal() * 0.05, b + rng.normal() * 0.05],
                    label,
                );
            }
        }
        d
    }

    #[test]
    fn learns_xor() {
        let d = xor_dataset();
        let mut rng = Rng::new(1);
        let t = DecisionTree::fit(&d, TreeConfig::default(), &mut rng);
        let preds = t.predict_batch(d.x());
        let acc = super::super::metrics::accuracy(&d.labels, &preds);
        assert!(acc > 0.98, "{acc}");
    }

    #[test]
    fn respects_max_depth() {
        let d = xor_dataset();
        let mut rng = Rng::new(2);
        let cfg = TreeConfig { max_depth: 1, ..Default::default() };
        let t = DecisionTree::fit(&d, cfg, &mut rng);
        assert!(t.depth() <= 1);
    }

    #[test]
    fn pure_node_is_leaf() {
        let mut d = Dataset::new();
        for i in 0..10 {
            d.push(vec![i as f64], 7);
        }
        let mut rng = Rng::new(3);
        let t = DecisionTree::fit(&d, TreeConfig::default(), &mut rng);
        assert!(matches!(t.root, Node::Leaf { .. }));
        assert_eq!(t.predict(&[3.0]), 7);
    }

    #[test]
    fn proba_sums_to_one() {
        let d = xor_dataset();
        let mut rng = Rng::new(4);
        let cfg = TreeConfig { max_depth: 2, ..Default::default() };
        let t = DecisionTree::fit(&d, cfg, &mut rng);
        let p = t.predict_proba(&[0.0, 1.0]).unwrap();
        let sum: f64 = p.iter().map(|(_, q)| q).sum();
        assert!((sum - 1.0).abs() < 1e-12);
    }

    #[test]
    fn constant_features_yield_leaf() {
        let mut d = Dataset::new();
        for i in 0..6 {
            d.push(vec![1.0, 1.0], (i % 2) as u32);
        }
        let mut rng = Rng::new(5);
        let t = DecisionTree::fit(&d, TreeConfig::default(), &mut rng);
        assert!(matches!(t.root, Node::Leaf { .. }));
    }
}
