//! Labelled dataset container + train/test utilities.

use crate::util::rng::Rng;

/// A dense labelled dataset. Rows are feature vectors, `labels[i]` is the
//  class of row i.
#[derive(Debug, Clone, Default)]
pub struct Dataset {
    pub rows: Vec<Vec<f64>>,
    pub labels: Vec<u32>,
}

impl Dataset {
    pub fn new() -> Dataset {
        Dataset::default()
    }

    pub fn push(&mut self, row: Vec<f64>, label: u32) {
        if let Some(first) = self.rows.first() {
            assert_eq!(first.len(), row.len(), "inconsistent feature width");
        }
        self.rows.push(row);
        self.labels.push(label);
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn width(&self) -> usize {
        self.rows.first().map(|r| r.len()).unwrap_or(0)
    }

    /// Distinct labels, sorted.
    pub fn classes(&self) -> Vec<u32> {
        let mut c = self.labels.clone();
        c.sort();
        c.dedup();
        c
    }

    /// Shuffled stratified split: returns (train, test) with `test_frac`
    /// of each class in the test set (at least one sample of each class
    /// stays in train).
    pub fn split(&self, rng: &mut Rng, test_frac: f64) -> (Dataset, Dataset) {
        assert!((0.0..1.0).contains(&test_frac));
        let mut train = Dataset::new();
        let mut test = Dataset::new();
        for class in self.classes() {
            let mut idx: Vec<usize> = (0..self.len())
                .filter(|&i| self.labels[i] == class)
                .collect();
            rng.shuffle(&mut idx);
            let n_test = ((idx.len() as f64) * test_frac).round() as usize;
            let n_test = n_test.min(idx.len().saturating_sub(1));
            for (k, &i) in idx.iter().enumerate() {
                let row = self.rows[i].clone();
                if k < n_test {
                    test.push(row, class);
                } else {
                    train.push(row, class);
                }
            }
        }
        (train, test)
    }

    /// Bootstrap resample of `n` rows (with replacement) — forest bagging.
    pub fn bootstrap(&self, rng: &mut Rng, n: usize) -> Dataset {
        let mut out = Dataset::new();
        for _ in 0..n {
            let i = rng.range_usize(0, self.len());
            out.push(self.rows[i].clone(), self.labels[i]);
        }
        out
    }

    /// Per-feature (mean, std) over the dataset — for standardising
    /// models that need it (kNN, logreg).
    pub fn feature_moments(&self) -> Vec<(f64, f64)> {
        let w = self.width();
        let n = self.len() as f64;
        let mut out = vec![(0.0, 0.0); w];
        for row in &self.rows {
            for (j, &v) in row.iter().enumerate() {
                out[j].0 += v;
            }
        }
        for m in out.iter_mut() {
            m.0 /= n;
        }
        for row in &self.rows {
            for (j, &v) in row.iter().enumerate() {
                let d = v - out[j].0;
                out[j].1 += d * d;
            }
        }
        for m in out.iter_mut() {
            m.1 = (m.1 / n).sqrt();
            if m.1 < 1e-12 {
                m.1 = 1.0; // constant feature: leave unscaled
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy(n_per_class: usize, classes: u32) -> Dataset {
        let mut d = Dataset::new();
        for c in 0..classes {
            for i in 0..n_per_class {
                d.push(vec![c as f64, i as f64], c);
            }
        }
        d
    }

    #[test]
    fn split_is_stratified_and_partitions() {
        let d = toy(20, 3);
        let mut rng = Rng::new(0);
        let (tr, te) = d.split(&mut rng, 0.25);
        assert_eq!(tr.len() + te.len(), d.len());
        for c in 0..3u32 {
            let n_te = te.labels.iter().filter(|&&l| l == c).count();
            assert_eq!(n_te, 5, "class {c}");
        }
    }

    #[test]
    fn split_keeps_train_nonempty_per_class() {
        let mut d = Dataset::new();
        d.push(vec![0.0], 0);
        d.push(vec![1.0], 0);
        let mut rng = Rng::new(1);
        let (tr, _) = d.split(&mut rng, 0.9);
        assert!(tr.labels.iter().any(|&l| l == 0));
    }

    #[test]
    fn bootstrap_size_and_membership() {
        let d = toy(10, 2);
        let mut rng = Rng::new(2);
        let b = d.bootstrap(&mut rng, 35);
        assert_eq!(b.len(), 35);
        for row in &b.rows {
            assert!(d.rows.contains(row));
        }
    }

    #[test]
    fn moments_standardise() {
        let mut d = Dataset::new();
        d.push(vec![0.0, 5.0], 0);
        d.push(vec![2.0, 5.0], 1);
        let m = d.feature_moments();
        assert!((m[0].0 - 1.0).abs() < 1e-12);
        assert!((m[0].1 - 1.0).abs() < 1e-12);
        assert_eq!(m[1].1, 1.0); // constant feature guard
    }

    #[test]
    #[should_panic(expected = "inconsistent")]
    fn width_mismatch_panics() {
        let mut d = Dataset::new();
        d.push(vec![1.0, 2.0], 0);
        d.push(vec![1.0], 0);
    }
}
