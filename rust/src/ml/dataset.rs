//! Labelled dataset container + train/test utilities, backed by the
//! contiguous `linalg::Matrix` row store (`labels[i]` is the class of
//! row `i`).

use crate::linalg::Matrix;
use crate::util::rng::Rng;

/// A dense labelled dataset over contiguous row storage.
#[derive(Debug, Clone, Default)]
pub struct Dataset {
    x: Matrix,
    pub labels: Vec<u32>,
}

impl Dataset {
    pub fn new() -> Dataset {
        Dataset::default()
    }

    /// Append one labelled row. Accepts any `[f64]`-like (slice, array,
    /// `Vec`, `&Vec`) so call sites stay allocation-agnostic.
    pub fn push<R: AsRef<[f64]>>(&mut self, row: R, label: u32) {
        let r = row.as_ref();
        if !self.x.is_empty() {
            assert_eq!(
                self.x.n_cols(),
                r.len(),
                "inconsistent feature width"
            );
        }
        self.x.push_row(r);
        self.labels.push(label);
    }

    /// Append every row of `other` (widths must agree).
    pub fn extend_from(&mut self, other: &Dataset) {
        self.x.extend_rows(&other.x);
        self.labels.extend_from_slice(&other.labels);
    }

    pub fn len(&self) -> usize {
        self.x.n_rows()
    }

    pub fn is_empty(&self) -> bool {
        self.x.is_empty()
    }

    pub fn width(&self) -> usize {
        self.x.n_cols()
    }

    /// The contiguous feature matrix.
    pub fn x(&self) -> &Matrix {
        &self.x
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        self.x.row(i)
    }

    /// Iterate `(row, label)` pairs.
    pub fn iter(&self) -> impl Iterator<Item = (&[f64], u32)> + '_ {
        self.x.iter_rows().zip(self.labels.iter().copied())
    }

    /// Distinct labels, sorted.
    pub fn classes(&self) -> Vec<u32> {
        let mut c = self.labels.clone();
        c.sort();
        c.dedup();
        c
    }

    /// Shuffled stratified split: returns (train, test) with `test_frac`
    /// of each class in the test set (at least one sample of each class
    /// stays in train).
    pub fn split(&self, rng: &mut Rng, test_frac: f64) -> (Dataset, Dataset) {
        assert!((0.0..1.0).contains(&test_frac));
        let mut train = Dataset::new();
        let mut test = Dataset::new();
        for class in self.classes() {
            let mut idx: Vec<usize> = (0..self.len())
                .filter(|&i| self.labels[i] == class)
                .collect();
            rng.shuffle(&mut idx);
            let n_test = ((idx.len() as f64) * test_frac).round() as usize;
            let n_test = n_test.min(idx.len().saturating_sub(1));
            for (k, &i) in idx.iter().enumerate() {
                if k < n_test {
                    test.push(self.row(i), class);
                } else {
                    train.push(self.row(i), class);
                }
            }
        }
        (train, test)
    }

    /// Bootstrap resample of `n` rows (with replacement) — forest bagging.
    pub fn bootstrap(&self, rng: &mut Rng, n: usize) -> Dataset {
        let mut out = Dataset::new();
        for _ in 0..n {
            let i = rng.range_usize(0, self.len());
            out.push(self.row(i), self.labels[i]);
        }
        out
    }

    /// Per-feature (mean, std) over the dataset — for standardising
    /// models that need it (kNN, logreg).
    pub fn feature_moments(&self) -> Vec<(f64, f64)> {
        let w = self.width();
        let n = self.len() as f64;
        let mut out = vec![(0.0, 0.0); w];
        for row in self.x.iter_rows() {
            for (j, &v) in row.iter().enumerate() {
                out[j].0 += v;
            }
        }
        for m in out.iter_mut() {
            m.0 /= n;
        }
        for row in self.x.iter_rows() {
            for (j, &v) in row.iter().enumerate() {
                let d = v - out[j].0;
                out[j].1 += d * d;
            }
        }
        for m in out.iter_mut() {
            m.1 = (m.1 / n).sqrt();
            if m.1 < 1e-12 {
                m.1 = 1.0; // constant feature: leave unscaled
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy(n_per_class: usize, classes: u32) -> Dataset {
        let mut d = Dataset::new();
        for c in 0..classes {
            for i in 0..n_per_class {
                d.push(vec![c as f64, i as f64], c);
            }
        }
        d
    }

    #[test]
    fn split_is_stratified_and_partitions() {
        let d = toy(20, 3);
        let mut rng = Rng::new(0);
        let (tr, te) = d.split(&mut rng, 0.25);
        assert_eq!(tr.len() + te.len(), d.len());
        for c in 0..3u32 {
            let n_te = te.labels.iter().filter(|&&l| l == c).count();
            assert_eq!(n_te, 5, "class {c}");
        }
    }

    #[test]
    fn split_keeps_train_nonempty_per_class() {
        let mut d = Dataset::new();
        d.push(vec![0.0], 0);
        d.push(vec![1.0], 0);
        let mut rng = Rng::new(1);
        let (tr, _) = d.split(&mut rng, 0.9);
        assert!(tr.labels.iter().any(|&l| l == 0));
    }

    #[test]
    fn bootstrap_size_and_membership() {
        let d = toy(10, 2);
        let mut rng = Rng::new(2);
        let b = d.bootstrap(&mut rng, 35);
        assert_eq!(b.len(), 35);
        for row in b.x().iter_rows() {
            assert!(d.x().iter_rows().any(|r| r == row));
        }
    }

    #[test]
    fn moments_standardise() {
        let mut d = Dataset::new();
        d.push(vec![0.0, 5.0], 0);
        d.push(vec![2.0, 5.0], 1);
        let m = d.feature_moments();
        assert!((m[0].0 - 1.0).abs() < 1e-12);
        assert!((m[0].1 - 1.0).abs() < 1e-12);
        assert_eq!(m[1].1, 1.0); // constant feature guard
    }

    #[test]
    fn extend_from_appends_rows_and_labels() {
        let mut a = toy(3, 2);
        let b = toy(2, 2);
        let n = a.len();
        a.extend_from(&b);
        assert_eq!(a.len(), n + b.len());
        assert_eq!(a.row(n), b.row(0));
    }

    #[test]
    #[should_panic(expected = "inconsistent")]
    fn width_mismatch_panics() {
        let mut d = Dataset::new();
        d.push(vec![1.0, 2.0], 0);
        d.push(vec![1.0], 0);
    }
}
