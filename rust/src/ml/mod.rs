//! From-scratch supervised ML for the KERMIT classifiers.
//!
//! The paper's WorkloadClassifier and TransitionClassifier are random
//! forests (§7.2); Fig 6 compares the forest against alternative
//! algorithms. All of them are implemented here natively in rust (trees
//! are branchy and poorly suited to XLA); the NN comparator (MLP) runs
//! through the PJRT artifact path in `runtime::nn` instead.

pub mod dataset;
pub mod forest;
pub mod knn;
pub mod linreg;
pub mod logreg;
pub mod metrics;
pub mod naive_bayes;
pub mod tree;

use crate::linalg::engine::Engine;
use crate::linalg::Matrix;

pub use dataset::Dataset;
pub use metrics::{accuracy, confusion_matrix, macro_f1, ClassMetrics};

/// Common interface for all native classifiers (Fig 6 harness iterates
/// over trait objects).
pub trait Classifier: Send + Sync {
    /// Predict the label of one feature vector.
    fn predict(&self, x: &[f64]) -> u32;

    /// Batch predict over contiguous rows (overridable for vectorised
    /// impls).
    fn predict_batch(&self, xs: &Matrix) -> Vec<u32> {
        xs.iter_rows().map(|x| self.predict(x)).collect()
    }

    /// Engine-parallel [`Classifier::predict_batch`]: rows fan out over
    /// the engine's persistent worker pool (every classifier is `Sync`,
    /// and each prediction is independent), producing exactly the
    /// labels of the sequential path. Small batches fall back to a
    /// single-threaded loop per the engine's threshold. No forced chunk
    /// alignment here: one prediction (a tree ensemble / neighbour
    /// scan) dwarfs a cache-line ping, and heavy items want the full
    /// `threads`-way split — callers can still opt in via their engine.
    fn predict_batch_with(&self, engine: Engine, xs: &Matrix) -> Vec<u32> {
        let mut out = vec![0u32; xs.n_rows()];
        engine.for_rows(&mut out, 1, |start, chunk| {
            for (off, cell) in chunk.iter_mut().enumerate() {
                *cell = self.predict(xs.row(start + off));
            }
        });
        out
    }

    /// Class-probability estimate if the model supports it (used by the
    /// plug-in to gate low-confidence classifications).
    fn predict_proba(&self, _x: &[f64]) -> Option<Vec<(u32, f64)>> {
        None
    }
}
