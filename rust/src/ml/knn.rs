//! k-nearest-neighbours comparator (Fig 6). Standardised features in a
//! contiguous `Matrix`, euclidean metric, distance-weighted vote. Batch
//! prediction inherits the engine-parallel `predict_batch_with` default
//! (persistent pool) from [`Classifier`]; each query row funnels
//! through `linalg::sq_dist`, so kNN rides whatever SIMD tier the
//! build compiled in.

use super::dataset::Dataset;
use super::Classifier;
use crate::linalg::{sq_dist, Matrix};
use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct Knn {
    k: usize,
    x: Matrix, // standardised rows
    labels: Vec<u32>,
    moments: Vec<(f64, f64)>,
}

impl Knn {
    pub fn fit(data: &Dataset, k: usize) -> Knn {
        assert!(!data.is_empty());
        let moments = data.feature_moments();
        let mut x = Matrix::zeros(data.len(), data.width());
        for i in 0..data.len() {
            standardise_into(data.row(i), &moments, x.row_mut(i));
        }
        Knn { k: k.max(1), x, labels: data.labels.clone(), moments }
    }
}

fn standardise_into(x: &[f64], moments: &[(f64, f64)], out: &mut [f64]) {
    for ((o, v), (m, s)) in out.iter_mut().zip(x).zip(moments) {
        *o = (v - m) / s;
    }
}

fn standardise(x: &[f64], moments: &[(f64, f64)]) -> Vec<f64> {
    let mut out = vec![0.0; x.len()];
    standardise_into(x, moments, &mut out);
    out
}

impl Classifier for Knn {
    fn predict(&self, x: &[f64]) -> u32 {
        self.predict_proba(x)
            .unwrap()
            .into_iter()
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .map(|(c, _)| c)
            .unwrap()
    }

    fn predict_proba(&self, x: &[f64]) -> Option<Vec<(u32, f64)>> {
        let xs = standardise(x, &self.moments);
        // partial top-k by distance over contiguous rows
        let mut dists: Vec<(f64, u32)> = self
            .x
            .iter_rows()
            .zip(&self.labels)
            .map(|(r, &l)| (sq_dist(r, &xs), l))
            .collect();
        let k = self.k.min(dists.len());
        dists.select_nth_unstable_by(k - 1, |a, b| {
            a.0.partial_cmp(&b.0).unwrap()
        });
        let mut votes: BTreeMap<u32, f64> = BTreeMap::new();
        for &(d, l) in &dists[..k] {
            *votes.entry(l).or_insert(0.0) += 1.0 / (d.sqrt() + 1e-9);
        }
        let total: f64 = votes.values().sum();
        Some(votes.into_iter().map(|(c, v)| (c, v / total)).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ml::metrics::accuracy;
    use crate::util::rng::Rng;

    #[test]
    fn classifies_separated_blobs() {
        let mut rng = Rng::new(0);
        let mut d = Dataset::new();
        for _ in 0..100 {
            d.push(vec![rng.normal_ms(0.0, 0.5), rng.normal_ms(0.0, 0.5)], 0);
            d.push(vec![rng.normal_ms(4.0, 0.5), rng.normal_ms(4.0, 0.5)], 1);
        }
        let (tr, te) = d.split(&mut rng, 0.3);
        let knn = Knn::fit(&tr, 5);
        let acc = accuracy(&te.labels, &knn.predict_batch(te.x()));
        assert!(acc > 0.97, "{acc}");
    }

    #[test]
    fn k_one_memorises_training_point() {
        let mut d = Dataset::new();
        d.push(vec![0.0], 0);
        d.push(vec![10.0], 1);
        let knn = Knn::fit(&d, 1);
        assert_eq!(knn.predict(&[0.1]), 0);
        assert_eq!(knn.predict(&[9.9]), 1);
    }

    #[test]
    fn k_larger_than_dataset_is_clamped() {
        let mut d = Dataset::new();
        d.push(vec![0.0], 0);
        d.push(vec![1.0], 0);
        let knn = Knn::fit(&d, 50);
        assert_eq!(knn.predict(&[0.5]), 0);
    }

    #[test]
    fn parallel_batch_matches_sequential() {
        use crate::linalg::engine::Engine;
        let mut rng = Rng::new(5);
        let mut d = Dataset::new();
        for _ in 0..150 {
            d.push(vec![rng.normal_ms(0.0, 1.0), rng.normal_ms(0.0, 1.0)], 0);
            d.push(vec![rng.normal_ms(3.0, 1.0), rng.normal_ms(3.0, 1.0)], 1);
        }
        let knn = Knn::fit(&d, 5);
        let seq = knn.predict_batch(d.x());
        for threads in [2, 4] {
            let engine = Engine::with_threads(threads).with_min_items(1);
            assert_eq!(seq, knn.predict_batch_with(engine, d.x()), "threads {threads}");
        }
    }

    #[test]
    fn standardisation_handles_scale_imbalance() {
        // feature 1 is 1000x feature 0's scale; without standardisation it
        // would dominate and mask the informative feature 0
        let mut rng = Rng::new(1);
        let mut d = Dataset::new();
        for _ in 0..80 {
            d.push(vec![0.0 + rng.normal() * 0.1, rng.normal() * 1000.0], 0);
            d.push(vec![1.0 + rng.normal() * 0.1, rng.normal() * 1000.0], 1);
        }
        let (tr, te) = d.split(&mut rng, 0.25);
        let knn = Knn::fit(&tr, 7);
        let acc = accuracy(&te.labels, &knn.predict_batch(te.x()));
        assert!(acc > 0.9, "{acc}");
    }
}
