//! Classification metrics: accuracy, confusion matrix, per-class
//! precision/recall/F1, macro-F1. Used by every Fig 6/7 bench and by the
//! off-line pipeline's self-evaluation.

use std::collections::BTreeMap;

/// Simple accuracy. Panics on length mismatch, returns 0 for empty.
pub fn accuracy(truth: &[u32], pred: &[u32]) -> f64 {
    assert_eq!(truth.len(), pred.len());
    if truth.is_empty() {
        return 0.0;
    }
    let hits = truth.iter().zip(pred).filter(|(a, b)| a == b).count();
    hits as f64 / truth.len() as f64
}

/// Confusion matrix keyed by (truth, pred).
pub fn confusion_matrix(truth: &[u32], pred: &[u32]) -> BTreeMap<(u32, u32), usize> {
    assert_eq!(truth.len(), pred.len());
    let mut m = BTreeMap::new();
    for (&t, &p) in truth.iter().zip(pred) {
        *m.entry((t, p)).or_insert(0) += 1;
    }
    m
}

/// Per-class precision / recall / F1.
#[derive(Debug, Clone, PartialEq)]
pub struct ClassMetrics {
    pub class: u32,
    pub precision: f64,
    pub recall: f64,
    pub f1: f64,
    pub support: usize,
}

pub fn per_class_metrics(truth: &[u32], pred: &[u32]) -> Vec<ClassMetrics> {
    let cm = confusion_matrix(truth, pred);
    let mut classes: Vec<u32> = truth.iter().chain(pred).copied().collect();
    classes.sort();
    classes.dedup();
    classes
        .into_iter()
        .map(|c| {
            let tp = *cm.get(&(c, c)).unwrap_or(&0) as f64;
            let fp: f64 = cm
                .iter()
                .filter(|((t, p), _)| *p == c && *t != c)
                .map(|(_, &n)| n as f64)
                .sum();
            let fn_: f64 = cm
                .iter()
                .filter(|((t, p), _)| *t == c && *p != c)
                .map(|(_, &n)| n as f64)
                .sum();
            let support = truth.iter().filter(|&&t| t == c).count();
            let precision = if tp + fp > 0.0 { tp / (tp + fp) } else { 0.0 };
            let recall = if tp + fn_ > 0.0 { tp / (tp + fn_) } else { 0.0 };
            let f1 = if precision + recall > 0.0 {
                2.0 * precision * recall / (precision + recall)
            } else {
                0.0
            };
            ClassMetrics { class: c, precision, recall, f1, support }
        })
        .collect()
}

/// Unweighted mean of per-class F1 (classes present in truth only).
pub fn macro_f1(truth: &[u32], pred: &[u32]) -> f64 {
    let per = per_class_metrics(truth, pred);
    let present: Vec<&ClassMetrics> =
        per.iter().filter(|m| m.support > 0).collect();
    if present.is_empty() {
        return 0.0;
    }
    present.iter().map(|m| m.f1).sum::<f64>() / present.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_basics() {
        assert_eq!(accuracy(&[1, 2, 3], &[1, 2, 4]), 2.0 / 3.0);
        assert_eq!(accuracy(&[], &[]), 0.0);
        assert_eq!(accuracy(&[5], &[5]), 1.0);
    }

    #[test]
    fn confusion_counts() {
        let cm = confusion_matrix(&[0, 0, 1, 1], &[0, 1, 1, 1]);
        assert_eq!(cm[&(0, 0)], 1);
        assert_eq!(cm[&(0, 1)], 1);
        assert_eq!(cm[&(1, 1)], 2);
        assert!(!cm.contains_key(&(1, 0)));
    }

    #[test]
    fn per_class_known_values() {
        // class 0: tp=1 fp=0 fn=1 -> p=1, r=0.5, f1=2/3
        // class 1: tp=2 fp=1 fn=0 -> p=2/3, r=1, f1=0.8
        let m = per_class_metrics(&[0, 0, 1, 1], &[0, 1, 1, 1]);
        let c0 = m.iter().find(|x| x.class == 0).unwrap();
        assert!((c0.precision - 1.0).abs() < 1e-12);
        assert!((c0.recall - 0.5).abs() < 1e-12);
        assert!((c0.f1 - 2.0 / 3.0).abs() < 1e-12);
        let c1 = m.iter().find(|x| x.class == 1).unwrap();
        assert!((c1.f1 - 0.8).abs() < 1e-12);
    }

    #[test]
    fn macro_f1_ignores_pred_only_classes() {
        // pred 9 never in truth -> not averaged
        let v = macro_f1(&[0, 0], &[0, 9]);
        // class 0: p=1.0, r=0.5, f1=2/3
        assert!((v - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn perfect_prediction() {
        let t = [3, 1, 4, 1, 5];
        assert_eq!(accuracy(&t, &t), 1.0);
        assert!((macro_f1(&t, &t) - 1.0).abs() < 1e-12);
    }
}
