//! Workload discovery, characterization, and drift detection —
//! Algorithm 2 (paper §7.1).
//!
//! On each off-line interval the analyser:
//! 1. runs the ChangeDetector in batch mode over the landed observation
//!    windows and extracts the transition windows;
//! 2. runs DBSCAN on the remaining steady-state windows (each cluster is
//!    a distinct workload type); the O(n²) distance matrix can be routed
//!    through the `pairwise_dist` PJRT artifact via [`DistanceProvider`];
//! 3. characterizes each cluster (mean/std/min/max/p75/p90 per feature);
//! 4. matches clusters against WorkloadDB: matched + mean-shift > ε ⇒
//!    drift (stored config kept, optimal flag cleared); matched without
//!    shift ⇒ refresh; unmatched ⇒ new label inserted.

use crate::clustering::{dbscan_with, DbscanConfig, DistanceProvider, NOISE};
use crate::features::{ObservationWindow, ANALYTIC_WIDTH};
use crate::knowledge::{Characterization, WorkloadDb};
use crate::linalg::engine::Engine;
use crate::linalg::Matrix;
use crate::online::change_detector::{ChangeDetector, ChangeDetectorConfig};

#[derive(Debug, Clone)]
pub struct DiscoveryConfig {
    pub change: ChangeDetectorConfig,
    pub dbscan: DbscanConfig,
    /// Nearest-characterization radius for "find match in WorkloadDB".
    pub match_radius: f64,
    /// The ε of Algorithm 2: matched clusters whose mean vector moved
    /// farther than this are flagged as drifting.
    pub drift_epsilon: f64,
    /// Compute engine for the off-line batch work (DBSCAN neighbourhood
    /// queries here, plus classifier retraining in the coordinator).
    /// Parallel engines dispatch onto the lazily-started persistent
    /// worker pool and produce bit-identical discovery results; the
    /// default stays single-threaded so plain constructions add no
    /// threading (and never start the pool).
    pub engine: Engine,
}

impl Default for DiscoveryConfig {
    fn default() -> Self {
        DiscoveryConfig {
            change: ChangeDetectorConfig::default(),
            dbscan: DbscanConfig { eps: 10.0, min_pts: 4 },
            match_radius: 25.0,
            drift_epsilon: 8.0,
            engine: Engine::sequential(),
        }
    }
}

/// What happened to one discovered cluster.
#[derive(Debug, Clone, PartialEq)]
pub enum ClusterOutcome {
    /// Matched an existing workload within drift tolerance.
    Matched { label: u32, distance: f64 },
    /// Matched an existing workload but beyond ε: drift flagged.
    Drifted { label: u32, distance: f64 },
    /// New workload: fresh label inserted.
    New { label: u32 },
}

/// Discovery report for one batch.
#[derive(Debug, Clone, Default)]
pub struct DiscoveryReport {
    /// Per input window: the workload label assigned (None for
    /// transition windows and DBSCAN noise).
    pub window_labels: Vec<Option<u32>>,
    /// Outcome per discovered cluster.
    pub outcomes: Vec<ClusterOutcome>,
    /// Count of windows flagged as transitions by the batch detector.
    pub transition_windows: usize,
    /// Count of steady windows DBSCAN left as noise.
    pub noise_windows: usize,
}

impl DiscoveryReport {
    pub fn new_labels(&self) -> Vec<u32> {
        self.outcomes
            .iter()
            .filter_map(|o| match o {
                ClusterOutcome::New { label } => Some(*label),
                _ => None,
            })
            .collect()
    }

    pub fn drifted_labels(&self) -> Vec<u32> {
        self.outcomes
            .iter()
            .filter_map(|o| match o {
                ClusterOutcome::Drifted { label, .. } => Some(*label),
                _ => None,
            })
            .collect()
    }
}

/// Run Algorithm 2 over a batch of observation windows, updating `db`.
pub fn discover(
    windows: &[ObservationWindow],
    db: &mut WorkloadDb,
    config: &DiscoveryConfig,
    dist: &dyn DistanceProvider,
) -> DiscoveryReport {
    let mut report = DiscoveryReport {
        window_labels: vec![None; windows.len()],
        ..Default::default()
    };
    if windows.is_empty() {
        return report;
    }

    // 1. flag + extract transition windows (batch ChangeDetector)
    let flags = ChangeDetector::batch(windows, &config.change);
    let steady_idx: Vec<usize> = (0..windows.len())
        .filter(|&i| !flags[i])
        .collect();
    report.transition_windows = windows.len() - steady_idx.len();

    // 2. DBSCAN on the steady windows' analytic features (written
    // straight into one contiguous matrix — no per-window Vec)
    let mut rows = Matrix::zeros(steady_idx.len(), ANALYTIC_WIDTH);
    for (r, &i) in steady_idx.iter().enumerate() {
        windows[i].write_analytic(rows.row_mut(r));
    }
    let clusters = dbscan_with(config.engine, &rows, &config.dbscan, dist);
    report.noise_windows =
        clusters.labels.iter().filter(|&&l| l == NOISE).count();

    // 3+4. characterize / match / drift / insert, per cluster
    for c in 0..clusters.n_clusters as i32 {
        let members = clusters.members(c);
        let member_rows = rows.gather(&members);
        let ch = Characterization::from_rows(&member_rows);
        let centroid = ch.mean_vector();

        let outcome = match db.nearest_observed(&ch) {
            Some((label, d)) if d <= config.match_radius => {
                if d > config.drift_epsilon {
                    db.mark_drifting(label, ch, centroid, members.len());
                    ClusterOutcome::Drifted { label, distance: d }
                } else {
                    db.refresh(label, ch, members.len());
                    ClusterOutcome::Matched { label, distance: d }
                }
            }
            _ => {
                let label =
                    db.insert_new(ch, centroid, members.len(), false);
                ClusterOutcome::New { label }
            }
        };
        let label = match &outcome {
            ClusterOutcome::Matched { label, .. }
            | ClusterOutcome::Drifted { label, .. }
            | ClusterOutcome::New { label } => *label,
        };
        for &m in &members {
            report.window_labels[steady_idx[m]] = Some(label);
        }
        report.outcomes.push(outcome);
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clustering::NativeDistance;
    use crate::features::NUM_FEATURES;
    use crate::monitor::{aggregate_trace, MonitorConfig};
    use crate::workloadgen::{tour_schedule, GenConfig, Generator, Mix, ScheduleEntry};

    fn run_tour(seed: u64, classes: &[u32], dur: usize) -> Vec<ObservationWindow> {
        let mut g = Generator::with_default_config(seed);
        let t = g.generate(&tour_schedule(dur, classes));
        aggregate_trace(&t, &MonitorConfig { window_size: 30 })
    }

    #[test]
    fn discovers_distinct_workloads_as_new_labels() {
        let ws = run_tour(0, &[0, 2, 5], 600);
        let mut db = WorkloadDb::new();
        let r = discover(&ws, &mut db, &DiscoveryConfig::default(), &NativeDistance);
        assert_eq!(db.len(), 3, "outcomes: {:?}", r.outcomes);
        assert_eq!(r.new_labels().len(), 3);
        // labelled windows dominate
        let labelled = r.window_labels.iter().filter(|l| l.is_some()).count();
        assert!(labelled * 10 > ws.len() * 7, "{labelled}/{}", ws.len());
    }

    #[test]
    fn rediscovery_matches_not_duplicates() {
        let mut db = WorkloadDb::new();
        let cfg = DiscoveryConfig::default();
        let ws1 = run_tour(1, &[0, 3], 500);
        discover(&ws1, &mut db, &cfg, &NativeDistance);
        assert_eq!(db.len(), 2);
        // second batch of the same classes: matched, no new labels
        let ws2 = run_tour(2, &[0, 3], 500);
        let r2 = discover(&ws2, &mut db, &cfg, &NativeDistance);
        assert_eq!(db.len(), 2, "outcomes: {:?}", r2.outcomes);
        assert!(r2.new_labels().is_empty());
    }

    #[test]
    fn drift_is_detected_and_flagged() {
        let mut db = WorkloadDb::new();
        let cfg = DiscoveryConfig::default();
        let ws1 = run_tour(3, &[1], 500);
        let r1 = discover(&ws1, &mut db, &cfg, &NativeDistance);
        let label = r1.new_labels()[0];

        // same class but drifted: shift two features by ~15 units
        let mut gen_cfg = GenConfig::default();
        let mut rate = [0.0; NUM_FEATURES];
        rate[0] = 15.0 / 500.0;
        rate[3] = 15.0 / 500.0;
        gen_cfg.drift_per_sample = vec![(1, rate)];
        let mut g = Generator::new(4, gen_cfg);
        let t = g.generate(&[ScheduleEntry { mix: Mix::Pure(1), duration: 500 }]);
        // take only the tail (fully drifted region)
        let tail: Vec<_> = t.samples[250..].to_vec();
        let ws2 = crate::monitor::aggregate_samples(
            &tail,
            &MonitorConfig { window_size: 30 },
        );
        let r2 = discover(&ws2, &mut db, &cfg, &NativeDistance);
        assert_eq!(r2.drifted_labels(), vec![label], "outcomes {:?}", r2.outcomes);
        assert!(db.get(label).unwrap().is_drifting);
    }

    #[test]
    fn empty_batch_is_noop() {
        let mut db = WorkloadDb::new();
        let r = discover(&[], &mut db, &DiscoveryConfig::default(), &NativeDistance);
        assert!(r.outcomes.is_empty());
        assert!(db.is_empty());
    }

    #[test]
    fn hybrid_workload_discovered_as_own_class() {
        let mut db = WorkloadDb::new();
        let cfg = DiscoveryConfig::default();
        // pure classes first
        let ws = run_tour(5, &[0, 1], 500);
        discover(&ws, &mut db, &cfg, &NativeDistance);
        assert_eq!(db.len(), 2);
        // now a 50/50 hybrid of 0+1: a genuinely new cluster
        let mut g = Generator::with_default_config(6);
        let t = g.generate(&[ScheduleEntry {
            mix: Mix::Hybrid(0, 1, 0.5),
            duration: 500,
        }]);
        let ws2 = aggregate_trace(&t, &MonitorConfig { window_size: 30 });
        let r = discover(&ws2, &mut db, &cfg, &NativeDistance);
        assert_eq!(r.new_labels().len(), 1, "outcomes {:?}", r.outcomes);
        assert_eq!(db.len(), 3);
    }
}
