//! Zero-shot workload anticipation — the WorkloadSynthesizer (paper
//! §7.2 step 7, and [9]).
//!
//! Multi-user clusters produce *hybrid* workloads: superpositions of
//! two tenants' jobs. KERMIT anticipates them before ever observing one:
//! every pair of known pure workloads yields a synthetic class whose
//! prototype blends the parents' characterizations; synthetic training
//! instances are sampled from that prototype and merged into the
//! WorkloadClassifier training set, so the on-line classifier can name a
//! hybrid the first time it appears.

use crate::knowledge::{Characterization, WorkloadDb};
use crate::ml::Dataset;
use crate::stats::Summary;
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct ZslConfig {
    /// Synthetic instances generated per anticipated class.
    pub instances_per_class: usize,
    /// Blend weight range for the first parent (w ~ U[lo, hi]).
    pub weight_lo: f64,
    pub weight_hi: f64,
}

impl Default for ZslConfig {
    fn default() -> Self {
        ZslConfig { instances_per_class: 40, weight_lo: 0.35, weight_hi: 0.65 }
    }
}

/// The synthesizer output: synthetic classes registered in the DB and
/// their training instances.
#[derive(Debug, Default)]
pub struct SynthesisReport {
    /// (synthetic label, parent a, parent b)
    pub classes: Vec<(u32, u32, u32)>,
    pub instances: Dataset,
}

/// Blend two characterizations at weight w (means blend linearly,
/// variances superpose with a cross-tenant interference term, matching
/// the generator's hybrid model).
pub fn blend_characterizations(
    a: &Characterization,
    b: &Characterization,
    w: f64,
) -> Characterization {
    let per_feature = a
        .per_feature
        .iter()
        .zip(&b.per_feature)
        .map(|(sa, sb)| {
            let mean = w * sa.mean + (1.0 - w) * sb.mean;
            let va = sa.std * sa.std;
            let vb = sb.std * sb.std;
            let var = w * w * va + (1.0 - w) * (1.0 - w) * vb
                + 0.25 * (va + vb);
            Summary {
                n: sa.n.min(sb.n),
                mean,
                std: var.sqrt(),
                min: w * sa.min + (1.0 - w) * sb.min,
                max: w * sa.max + (1.0 - w) * sb.max,
                p75: w * sa.p75 + (1.0 - w) * sb.p75,
                p90: w * sa.p90 + (1.0 - w) * sb.p90,
            }
        })
        .collect();
    Characterization { per_feature }
}

/// Generate the Class-Descriptor pairing (step 7a), register synthetic
/// prototypes in the DB (7c), and emit merged training instances (7d).
///
/// Pure = non-synthetic entries currently in the DB. Pairs that already
/// have a synthetic entry are skipped (idempotent across off-line runs).
pub fn synthesize(
    db: &mut WorkloadDb,
    config: &ZslConfig,
    rng: &mut Rng,
) -> SynthesisReport {
    let mut report = SynthesisReport::default();
    let pure: Vec<u32> = db
        .entries()
        .filter(|e| !e.synthetic)
        .map(|e| e.label)
        .collect();

    for (i, &a) in pure.iter().enumerate() {
        for &b in pure.iter().skip(i + 1) {
            // idempotence: one synthetic class per parent pair, ever
            if db.has_synthetic_pair(a, b) {
                continue;
            }
            let (ca, cb) = (
                db.get(a).unwrap().characterization.clone(),
                db.get(b).unwrap().characterization.clone(),
            );
            let proto = blend_characterizations(&ca, &cb, 0.5);
            let centroid = proto.mean_vector();
            let label = db.insert_with_parents(
                proto.clone(),
                centroid,
                0, // no observed windows
                true,
                Some(if a < b { (a, b) } else { (b, a) }),
            );
            report.classes.push((label, a, b));
            // synthetic instances: gaussian around blended stats with
            // per-instance blend-weight jitter (multi-user mixes vary)
            for _ in 0..config.instances_per_class {
                let w = rng.range_f64(config.weight_lo, config.weight_hi);
                let inst = blend_characterizations(&ca, &cb, w);
                let row: Vec<f64> = inst
                    .per_feature
                    .iter()
                    .map(|s| rng.normal_ms(s.mean, s.std.max(1e-6)))
                    .collect();
                report.instances.push(row, label);
            }
        }
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    fn char_at(level: f64, spread: f64) -> Characterization {
        let rows: Vec<Vec<f64>> = (0..8)
            .map(|i| vec![level + spread * (i % 3) as f64, 2.0 * level])
            .collect();
        Characterization::from_vec_rows(&rows)
    }

    fn db_with_pure(levels: &[f64]) -> WorkloadDb {
        let mut db = WorkloadDb::new();
        for &l in levels {
            let c = char_at(l, 1.0);
            let cen = c.mean_vector();
            db.insert_new(c, cen, 8, false);
        }
        db
    }

    #[test]
    fn synthesizes_all_pairs() {
        let mut db = db_with_pure(&[0.0, 10.0, 30.0]);
        let mut rng = Rng::new(0);
        let r = synthesize(&mut db, &ZslConfig::default(), &mut rng);
        assert_eq!(r.classes.len(), 3); // C(3,2)
        assert_eq!(db.len(), 6);
        assert_eq!(
            r.instances.len(),
            3 * ZslConfig::default().instances_per_class
        );
        // synthetic entries flagged
        for (label, _, _) in &r.classes {
            assert!(db.get(*label).unwrap().synthetic);
        }
    }

    #[test]
    fn idempotent_across_runs() {
        let mut db = db_with_pure(&[0.0, 10.0]);
        let mut rng = Rng::new(1);
        let r1 = synthesize(&mut db, &ZslConfig::default(), &mut rng);
        assert_eq!(r1.classes.len(), 1);
        let r2 = synthesize(&mut db, &ZslConfig::default(), &mut rng);
        assert!(r2.classes.is_empty(), "second run must not duplicate");
        assert_eq!(db.len(), 3);
    }

    #[test]
    fn blend_midpoint_mean() {
        let a = char_at(0.0, 0.5);
        let b = char_at(10.0, 0.5);
        let m = blend_characterizations(&a, &b, 0.5);
        let want = 0.5 * (a.per_feature[0].mean + b.per_feature[0].mean);
        assert!((m.per_feature[0].mean - want).abs() < 1e-12);
        // interference term keeps variance strictly positive
        assert!(m.per_feature[0].std > 0.0);
    }

    #[test]
    fn instances_center_near_prototype() {
        let mut db = db_with_pure(&[0.0, 20.0]);
        let mut rng = Rng::new(2);
        let cfg = ZslConfig { instances_per_class: 300, ..Default::default() };
        let r = synthesize(&mut db, &cfg, &mut rng);
        let (label, _, _) = r.classes[0];
        let proto = db.get(label).unwrap().centroid.clone();
        let rows: Vec<&[f64]> = r
            .instances
            .iter()
            .filter(|&(_, l)| l == label)
            .map(|(r, _)| r)
            .collect();
        let mean0: f64 =
            rows.iter().map(|r| r[0]).sum::<f64>() / rows.len() as f64;
        assert!((mean0 - proto[0]).abs() < 1.5, "{mean0} vs {}", proto[0]);
    }
}
