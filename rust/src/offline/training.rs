//! Automated classifier training — the off-line ML pipeline of §7.2.
//!
//! Implements the nine training steps: extract per-workload window
//! ranges, build the WorkloadClassifier training set from analytic
//! windows, establish transition ranges and generate transition labels,
//! apply the rate-of-change transform for the TransitionClassifier set,
//! run the ZSL WorkloadSynthesizer and merge its instances, extract the
//! label sequence for the WorkloadPredictor, and fit the classifiers.
//! No human labelling anywhere: every label comes from discovery
//! (cluster ids) or generation (transition pair ids, synthetic ids).

use super::discovery::DiscoveryReport;
use super::zsl::{synthesize, ZslConfig};
use crate::features::{rate_of_change, AnalyticWindow, ObservationWindow};
use crate::knowledge::WorkloadDb;
use crate::ml::forest::{ForestConfig, RandomForest};
use crate::ml::Dataset;
use crate::util::rng::Rng;
use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct TrainingConfig {
    pub forest: ForestConfig,
    pub zsl: ZslConfig,
    /// Run the ZSL synthesizer and merge synthetic instances.
    pub enable_zsl: bool,
}

impl Default for TrainingConfig {
    fn default() -> Self {
        TrainingConfig {
            forest: ForestConfig::default(),
            zsl: ZslConfig::default(),
            enable_zsl: true,
        }
    }
}

/// Everything the on-line sub-system needs after a training run.
pub struct TrainedModels {
    /// The WorkloadClassifier (random forest over analytic windows).
    pub workload_forest: RandomForest,
    /// The TransitionClassifier (random forest over rate-of-change
    /// windows), None when the batch contained no transitions.
    pub transition_forest: Option<RandomForest>,
    /// Transition-label registry: (from_label, to_label) -> generated id.
    pub transition_labels: BTreeMap<(u32, u32), u32>,
    /// Label sequence for the WorkloadPredictor (consecutive duplicates
    /// collapsed).
    pub label_sequence: Vec<u32>,
    /// Training-set sizes (telemetry).
    pub workload_set_size: usize,
    pub transition_set_size: usize,
}

/// Build the WorkloadClassifier training set: analytic windows labelled
/// by their discovery cluster label (steps 1-2).
pub fn workload_training_set(
    windows: &[ObservationWindow],
    report: &DiscoveryReport,
) -> Dataset {
    let mut d = Dataset::new();
    for (w, label) in windows.iter().zip(&report.window_labels) {
        if let Some(l) = label {
            d.push(AnalyticWindow::from_observation(w).features, *l);
        }
    }
    d
}

/// Build the TransitionClassifier training set (steps 3-6): scan the
/// window sequence; maximal runs of unlabelled windows bounded by two
/// labelled ones form a transition of type (from, to); features are the
/// rate-of-change transform of the surrounding analytic windows.
/// Transition labels are generated integers, consistent across calls via
/// the registry.
pub fn transition_training_set(
    windows: &[ObservationWindow],
    report: &DiscoveryReport,
    registry: &mut BTreeMap<(u32, u32), u32>,
) -> Dataset {
    let analytic: Vec<AnalyticWindow> =
        windows.iter().map(AnalyticWindow::from_observation).collect();
    let rocs = rate_of_change(&analytic); // rocs[i] = a[i+1] - a[i]
    let labels = &report.window_labels;
    let mut d = Dataset::new();

    let mut i = 0;
    while i < windows.len() {
        if labels[i].is_none() {
            // find the run of unlabelled windows [i, j)
            let mut j = i;
            while j < windows.len() && labels[j].is_none() {
                j += 1;
            }
            let from = if i > 0 { labels[i - 1] } else { None };
            let to = if j < windows.len() { labels[j] } else { None };
            if let (Some(f), Some(t)) = (from, to) {
                if f != t {
                    let next_id = registry.len() as u32;
                    let id = *registry.entry((f, t)).or_insert(next_id);
                    // rate-of-change rows spanning the run: indices
                    // i-1 .. j-1 in roc space cover the ramp deltas
                    for k in i.saturating_sub(1)..j.min(rocs.len()) {
                        d.push(&rocs[k].features, id);
                    }
                }
            }
            i = j;
        } else {
            i += 1;
        }
    }
    d
}

/// Extract the predictor label sequence (step 8): labelled windows in
/// order, consecutive duplicates collapsed.
pub fn label_sequence(report: &DiscoveryReport) -> Vec<u32> {
    let mut seq = Vec::new();
    for l in report.window_labels.iter().flatten() {
        if seq.last() != Some(l) {
            seq.push(*l);
        }
    }
    seq
}

/// The full pipeline (step 9 trains the forests).
pub fn train(
    windows: &[ObservationWindow],
    report: &DiscoveryReport,
    db: &mut WorkloadDb,
    config: &TrainingConfig,
    rng: &mut Rng,
) -> TrainedModels {
    let mut workload_set = workload_training_set(windows, report);

    if config.enable_zsl {
        let synth = synthesize(db, &config.zsl, rng);
        workload_set.extend_from(&synth.instances);
    }

    let mut registry = BTreeMap::new();
    let transition_set =
        transition_training_set(windows, report, &mut registry);

    let workload_forest =
        RandomForest::fit(&workload_set, config.forest.clone(), rng);
    let transition_forest = if transition_set.is_empty() {
        None
    } else {
        Some(RandomForest::fit(&transition_set, config.forest.clone(), rng))
    };

    TrainedModels {
        workload_forest,
        transition_forest,
        transition_labels: registry,
        label_sequence: label_sequence(report),
        workload_set_size: workload_set.len(),
        transition_set_size: transition_set.len(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clustering::NativeDistance;
    use crate::ml::{accuracy, Classifier};
    use crate::monitor::{aggregate_trace, MonitorConfig};
    use crate::offline::discovery::{discover, DiscoveryConfig};
    use crate::workloadgen::{tour_schedule, Generator};

    fn setup(seed: u64, classes: &[u32]) -> (Vec<ObservationWindow>, DiscoveryReport, WorkloadDb) {
        let mut g = Generator::with_default_config(seed);
        let t = g.generate(&tour_schedule(400, classes));
        let ws = aggregate_trace(&t, &MonitorConfig { window_size: 20 });
        let mut db = WorkloadDb::new();
        let r = discover(&ws, &mut db, &DiscoveryConfig::default(), &NativeDistance);
        (ws, r, db)
    }

    #[test]
    fn end_to_end_training_classifies_heldout_windows() {
        let (ws, r, mut db) = setup(0, &[0, 2, 5, 7]);
        let mut rng = Rng::new(1);
        let models = train(&ws, &r, &mut db, &TrainingConfig::default(), &mut rng);
        assert!(models.workload_set_size > 50);

        // held-out trace of the same classes: forest must label windows
        // with the same discovery labels
        let mut g = Generator::with_default_config(99);
        let t2 = g.generate(&tour_schedule(200, &[0, 2, 5, 7]));
        let ws2 = aggregate_trace(&t2, &MonitorConfig { window_size: 20 });
        let mut db2 = db;
        let r2 = discover(&ws2, &mut db2, &DiscoveryConfig::default(), &NativeDistance);
        let heldout = workload_training_set(&ws2, &r2);
        let preds = models.workload_forest.predict_batch(heldout.x());
        let acc = accuracy(&heldout.labels, &preds);
        assert!(acc > 0.9, "held-out accuracy {acc}");
    }

    #[test]
    fn transition_set_has_labels_per_pair() {
        let (ws, r, _) = setup(2, &[0, 2, 5]);
        let mut reg = BTreeMap::new();
        let d = transition_training_set(&ws, &r, &mut reg);
        // tour 0->2->5 has two distinct transitions
        assert_eq!(reg.len(), 2, "registry {reg:?}");
        assert!(!d.is_empty());
        // ids are 0..n
        let mut ids: Vec<u32> = reg.values().copied().collect();
        ids.sort();
        assert_eq!(ids, vec![0, 1]);
    }

    #[test]
    fn label_sequence_collapses_duplicates() {
        let report = DiscoveryReport {
            window_labels: vec![
                Some(3), Some(3), None, Some(5), Some(5), Some(3),
            ],
            ..Default::default()
        };
        assert_eq!(label_sequence(&report), vec![3, 5, 3]);
    }

    #[test]
    fn zsl_expands_training_set() {
        let (ws, r, mut db) = setup(3, &[0, 4]);
        let mut rng = Rng::new(4);
        let no_zsl = train(
            &ws, &r, &mut db.clone_for_test(),
            &TrainingConfig { enable_zsl: false, ..Default::default() },
            &mut rng,
        );
        let with_zsl = train(
            &ws, &r, &mut db,
            &TrainingConfig::default(),
            &mut rng,
        );
        assert!(with_zsl.workload_set_size > no_zsl.workload_set_size);
        // the synthetic hybrid class is registered in the DB
        assert!(db.entries().any(|e| e.synthetic));
    }
}

#[cfg(test)]
impl WorkloadDb {
    /// test helper: deep copy via json round-trip
    fn clone_for_test(&self) -> WorkloadDb {
        WorkloadDb::from_json(&self.to_json()).unwrap()
    }
}
