//! The KERMIT off-line sub-system (§7): batch workload discovery and
//! characterization (Algorithm 2), drift detection, zero-shot workload
//! anticipation, and automated classifier training.

pub mod discovery;
pub mod training;
pub mod zsl;

pub use discovery::{discover, ClusterOutcome, DiscoveryConfig, DiscoveryReport};
pub use training::{train, TrainedModels, TrainingConfig};
pub use zsl::{blend_characterizations, synthesize, SynthesisReport, ZslConfig};
