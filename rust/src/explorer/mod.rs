//! The Explorer configuration-search algorithm and its baselines.
//!
//! Reimplemented from the description in [16] (Genkin et al., HPCC'16)
//! and §6.4: a "low-overhead, conceptually simple" search that the
//! KERMIT plug-in engages when the resource manager responds to a
//! resource request. Two entry points, exactly as Algorithm 1 uses them:
//!
//! * [`Explorer::global_search`] — for a newly discovered workload with
//!   no stored configuration;
//! * [`Explorer::local_search`]  — re-optimisation seeded at the last
//!   good configuration after workload drift.
//!
//! Baselines for the tuning-efficiency experiment (EXPERIMENTS.md):
//! rule-of-thumb (human heuristics), exhaustive grid (the 100% oracle),
//! and random search.

pub mod baselines;
pub mod session;

use crate::simcluster::config_space::{default_config_index, ConfigIndex, NUM_DIMS};

/// Measurement callback: run (or simulate) the workload under a config
/// and return its duration. Each call is one "probe" — the costly
/// operation Explorer minimises.
pub trait ConfigEvaluator {
    fn measure(&mut self, config: ConfigIndex) -> f64;
}

impl<F: FnMut(ConfigIndex) -> f64> ConfigEvaluator for F {
    fn measure(&mut self, config: ConfigIndex) -> f64 {
        self(config)
    }
}

/// Search report: best config found, its measured duration, probes used.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SearchResult {
    pub best: ConfigIndex,
    pub best_duration: f64,
    pub probes: usize,
}

#[derive(Debug, Clone)]
pub struct ExplorerConfig {
    /// Hard probe budget for a global search.
    pub global_budget: usize,
    /// Hard probe budget for a local (drift) search.
    pub local_budget: usize,
    /// Relative improvement below which a coordinate pass stops early.
    pub min_improvement: f64,
}

impl Default for ExplorerConfig {
    fn default() -> Self {
        // 140 probes is 0.9% of the 15552-point grid — still "low
        // overhead" in the paper's sense, and enough for line-scan
        // convergence plus the 2-D interaction scans.
        ExplorerConfig {
            global_budget: 140,
            local_budget: 24,
            min_improvement: 0.002,
        }
    }
}

/// For each executor-count level, the densest configuration that still
/// fits the cluster: max cores level with cores*executors <= capacity,
/// max memory level with mem*executors <= capacity. Mid-range shuffle /
/// parallelism; compression on (descent flips it in one move if wrong).
pub fn packed_seeds() -> Vec<ConfigIndex> {
    use crate::simcluster::config_space::{
        CORE_LEVELS, EXEC_LEVELS, MEM_LEVELS,
    };
    use crate::simcluster::perfmodel::{CLUSTER_CORES, CLUSTER_MEM_MB};
    let mut out = Vec::new();
    for (ei, &execs) in EXEC_LEVELS.iter().enumerate() {
        let ci = CORE_LEVELS
            .iter()
            .rposition(|&c| c * execs <= CLUSTER_CORES);
        let mi = MEM_LEVELS
            .iter()
            .rposition(|&m| m * execs <= CLUSTER_MEM_MB);
        if let (Some(ci), Some(mi)) = (ci, mi) {
            out.push(ConfigIndex([mi, ci, ei, 3, 3, 1]));
        }
    }
    out
}

/// Coordinate-descent explorer with diagonal seed probing.
pub struct Explorer {
    pub config: ExplorerConfig,
}

impl Explorer {
    pub fn new(config: ExplorerConfig) -> Explorer {
        Explorer { config }
    }

    pub fn with_defaults() -> Explorer {
        Explorer::new(ExplorerConfig::default())
    }

    /// Global search: probe a coarse diagonal of the space (small /
    /// medium / large resource footprints plus the vendor default), then
    /// run coordinate descent from the best seed.
    pub fn global_search(&self, eval: &mut dyn ConfigEvaluator) -> SearchResult {
        let dims = ConfigIndex::dims();
        let mut probes = 0usize;
        let budget = self.config.global_budget;

        // seed set: default + low/mid/high diagonal + "big memory" point
        // + packed-cluster seeds (configs that exactly fill the cluster —
        // what a performance engineer tries first; these sit on the
        // 3-way mem×cores×executors ridge that coordinate moves cannot
        // reach from the interior).
        let mid = ConfigIndex([
            dims[0] / 2, dims[1] / 2, dims[2] / 2,
            dims[3] / 2, dims[4] / 2, dims[5] / 2,
        ]);
        let high = ConfigIndex([
            dims[0] - 2, dims[1] - 2, dims[2] - 2,
            dims[3] - 2, dims[4] - 2, dims[5] - 1,
        ]).clamped();
        let bigmem = ConfigIndex([dims[0] - 1, 2, dims[2] / 2, 2, 2, 0]);
        let mut seeds = vec![default_config_index(), mid, high, bigmem];
        seeds.extend(packed_seeds());

        let mut best = (f64::INFINITY, seeds[0]);
        for &s in seeds.iter() {
            if probes >= budget {
                break;
            }
            let d = eval.measure(s);
            probes += 1;
            if d < best.0 {
                best = (d, s);
            }
        }

        let r = self.descend(best.1, best.0, eval, budget, &mut probes);
        SearchResult { best: r.1, best_duration: r.0, probes }
    }

    /// Local search: coordinate descent from `start` under the smaller
    /// drift budget (Algorithm 1's `Explorer.localSearch(J_i)`).
    pub fn local_search(
        &self,
        start: ConfigIndex,
        eval: &mut dyn ConfigEvaluator,
    ) -> SearchResult {
        let mut probes = 0usize;
        let d0 = eval.measure(start);
        probes += 1;
        let r = self.descend(start, d0, eval, self.config.local_budget, &mut probes);
        SearchResult { best: r.1, best_duration: r.0, probes }
    }

    /// Line-scan coordinate descent plus 2-D interaction scans.
    ///
    /// 1-D pass: for each dimension, evaluate every level (memoised, so
    /// revisits are free) and move to the argmin. This crosses 1-D
    /// ridges like the memory cliff. The tuning surface also has strong
    /// *pairwise* interactions — executor memory × cores sets the
    /// per-task heap, cores × executors sets the slot count against
    /// cluster capacity — where no single-coordinate move improves, so
    /// after 1-D convergence the search scans those 2-D subgrids and
    /// resumes 1-D sweeps if they improve.
    fn descend(
        &self,
        start: ConfigIndex,
        start_duration: f64,
        eval: &mut dyn ConfigEvaluator,
        budget: usize,
        probes: &mut usize,
    ) -> (f64, ConfigIndex) {
        let dims = ConfigIndex::dims();
        let mut memo: std::collections::HashMap<ConfigIndex, f64> =
            std::collections::HashMap::new();
        memo.insert(start, start_duration);
        let mut best = (start_duration, start);

        // measure-with-memo helper; returns None when budget exhausted
        let mut probe = |cand: ConfigIndex,
                         memo: &mut std::collections::HashMap<ConfigIndex, f64>,
                         probes: &mut usize|
         -> Option<f64> {
            if let Some(&v) = memo.get(&cand) {
                return Some(v);
            }
            if *probes >= budget {
                return None;
            }
            let v = eval.measure(cand);
            *probes += 1;
            memo.insert(cand, v);
            Some(v)
        };

        // interacting dimension pairs scanned after 1-D convergence:
        // (mem, cores) -> per-task heap; (cores, executors) -> slots vs
        // capacity; (executors, parallelism) -> wave quantisation.
        const PAIRS: [(usize, usize); 3] = [(0, 1), (1, 2), (2, 4)];

        'outer: loop {
            // ---- 1-D line-scan sweeps until stable
            loop {
                let sweep_start = best.0;
                for d in 0..NUM_DIMS {
                    let mut dim_best = best;
                    for level in 0..dims[d] {
                        let mut cand = best.1;
                        cand.0[d] = level;
                        if cand == best.1 {
                            continue;
                        }
                        match probe(cand, &mut memo, probes) {
                            Some(dur) if dur < dim_best.0 => {
                                dim_best = (dur, cand)
                            }
                            Some(_) => {}
                            None => return best,
                        }
                    }
                    best = dim_best;
                }
                let gained = (sweep_start - best.0) / sweep_start.max(1e-9);
                // No-progress sweeps must terminate unconditionally:
                // memoised revisits make them free, so relying on
                // min_improvement alone would spin forever. The negated
                // form also catches NaN (e.g. all-INFINITY measurements
                // when a session is abandoned mid-search).
                if !(gained > 0.0 && gained >= self.config.min_improvement) {
                    break;
                }
            }

            // ---- 2-D interaction scans; resume 1-D sweeps on improvement
            let before_pairs = best.0;
            for (da, db) in PAIRS {
                for la in 0..dims[da] {
                    for lb in 0..dims[db] {
                        let mut cand = best.1;
                        cand.0[da] = la;
                        cand.0[db] = lb;
                        if cand == best.1 {
                            continue;
                        }
                        match probe(cand, &mut memo, probes) {
                            Some(dur) if dur < best.0 => best = (dur, cand),
                            Some(_) => {}
                            None => return best,
                        }
                    }
                }
            }
            let gained = (before_pairs - best.0) / before_pairs.max(1e-9);
            // negated form: also terminates on NaN (see above)
            if !(gained > 0.0 && gained >= self.config.min_improvement) {
                return best;
            }
            continue 'outer;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simcluster::config_space::ConfigIndex;
    use crate::simcluster::perfmodel::job_duration;

    struct Counting<F: FnMut(ConfigIndex) -> f64> {
        f: F,
        calls: usize,
    }

    impl<F: FnMut(ConfigIndex) -> f64> ConfigEvaluator for Counting<F> {
        fn measure(&mut self, c: ConfigIndex) -> f64 {
            self.calls += 1;
            (self.f)(c)
        }
    }

    fn exhaustive_best(class: u32) -> f64 {
        ConfigIndex::enumerate_all()
            .into_iter()
            .map(|ci| job_duration(class, &ci.to_config()))
            .fold(f64::INFINITY, f64::min)
    }

    #[test]
    fn respects_budget() {
        let ex = Explorer::new(ExplorerConfig {
            global_budget: 10,
            local_budget: 5,
            min_improvement: 0.0,
        });
        let mut eval = Counting { f: |c: ConfigIndex| job_duration(2, &c.to_config()), calls: 0 };
        let r = ex.global_search(&mut eval);
        assert!(r.probes <= 10);
        assert_eq!(eval.calls, r.probes);
    }

    #[test]
    fn global_search_near_oracle_on_all_classes() {
        // the paper's claim: >= 92% tuning efficiency (oracle/found)
        let ex = Explorer::with_defaults();
        for class in 0..crate::workloadgen::num_pure_classes() as u32 {
            let mut eval = |c: ConfigIndex| job_duration(class, &c.to_config());
            let r = ex.global_search(&mut eval);
            let oracle = exhaustive_best(class);
            let eff = oracle / r.best_duration;
            assert!(
                eff >= 0.80,
                "class {class}: eff {eff:.3} ({} vs oracle {oracle})",
                r.best_duration
            );
        }
    }

    #[test]
    fn local_search_recovers_from_nearby_start() {
        let ex = Explorer::with_defaults();
        // perturb the known-good region by one step and re-optimise
        let mut eval = |c: ConfigIndex| job_duration(3, &c.to_config());
        let g = ex.global_search(&mut eval);
        let mut start = g.best;
        start.0[0] = if start.0[0] > 0 { start.0[0] - 1 } else { 1 };
        let l = ex.local_search(start, &mut eval);
        assert!(l.best_duration <= eval(start));
        assert!(l.probes <= ExplorerConfig::default().local_budget + 1);
    }

    #[test]
    fn returned_duration_matches_config() {
        let ex = Explorer::with_defaults();
        let mut eval = |c: ConfigIndex| job_duration(4, &c.to_config());
        let r = ex.global_search(&mut eval);
        assert!((eval(r.best) - r.best_duration).abs() < 1e-9);
    }

    #[test]
    fn monotone_surface_reaches_corner() {
        // toy surface where smaller indices are strictly better: descent
        // must find the [0,...,0] corner from any seed
        let ex = Explorer::new(ExplorerConfig {
            global_budget: 200,
            local_budget: 50,
            min_improvement: 0.0,
        });
        let mut eval =
            |c: ConfigIndex| c.0.iter().map(|&x| x as f64).sum::<f64>() + 1.0;
        let r = ex.global_search(&mut eval);
        assert_eq!(r.best, ConfigIndex([0; 6]));
        assert_eq!(r.best_duration, 1.0);
    }
}
