//! Incremental search sessions: the Explorer inverted into a coroutine.
//!
//! On a real cluster a configuration probe *is* one execution of the
//! workload — the search proceeds across successive runs (that is what
//! makes on-line tuning "on-line" in [16]). `SearchSession` runs the
//! Explorer on its own thread; its evaluator hands each candidate config
//! to the plug-in through a channel and blocks until the plug-in reports
//! the measured duration of that run. Strict alternation (one candidate
//! out, one measurement in) makes the protocol deadlock-free.

use super::{ConfigEvaluator, Explorer, ExplorerConfig, SearchResult};
use crate::simcluster::config_space::ConfigIndex;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

/// What the session yields when asked for the next probe.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SessionStep {
    /// Run the workload under this configuration and report back.
    Probe(ConfigIndex),
    /// Search finished: the final result.
    Done(SearchResult),
    /// Search gave up — step cap exhausted or too many consecutive
    /// failed measurements. Carries the best *finite* probe seen (if
    /// any); it is advisory, never a trusted optimum.
    Abandoned(Option<SearchResult>),
}

struct ChannelEvaluator {
    tx_cand: Sender<ConfigIndex>,
    rx_meas: Receiver<f64>,
}

impl ConfigEvaluator for ChannelEvaluator {
    fn measure(&mut self, config: ConfigIndex) -> f64 {
        // If the session was dropped, unblock with a poisoned value; the
        // search result is discarded anyway.
        if self.tx_cand.send(config).is_err() {
            return f64::INFINITY;
        }
        self.rx_meas.recv().unwrap_or(f64::INFINITY)
    }
}

/// A paused Explorer search, advanced one probe per workload execution.
///
/// Two liveness guards (both off by default — `usize::MAX`) keep a
/// session from livelocking on a faulty cluster: a *step cap* bounds
/// the total probes it may ask for, and a *failed-streak cap* abandons
/// the search after that many consecutive failed (non-finite)
/// measurements. A tripped guard yields [`SessionStep::Abandoned`] and
/// tears the explorer thread down.
pub struct SearchSession {
    rx_cand: Receiver<ConfigIndex>,
    tx_meas: Sender<f64>,
    handle: Option<JoinHandle<SearchResult>>,
    outstanding: bool,
    finished: Option<SearchResult>,
    steps: usize,
    step_cap: usize,
    failed_streak: usize,
    max_failed_streak: usize,
    last_probe: Option<ConfigIndex>,
    /// Best finite measurement seen: (duration, config).
    best_seen: Option<(f64, ConfigIndex)>,
    abandoned: bool,
}

impl SearchSession {
    /// Start a global search session.
    pub fn global(config: ExplorerConfig) -> SearchSession {
        Self::spawn(config, None)
    }

    /// Start a local (drift) search session from `start`.
    pub fn local(config: ExplorerConfig, start: ConfigIndex) -> SearchSession {
        Self::spawn(config, Some(start))
    }

    fn spawn(config: ExplorerConfig, start: Option<ConfigIndex>) -> SearchSession {
        let (tx_cand, rx_cand) = channel();
        let (tx_meas, rx_meas) = channel();
        let handle = std::thread::spawn(move || {
            let mut eval = ChannelEvaluator { tx_cand, rx_meas };
            let ex = Explorer::new(config);
            match start {
                Some(s) => ex.local_search(s, &mut eval),
                None => ex.global_search(&mut eval),
            }
        });
        SearchSession {
            rx_cand,
            tx_meas,
            handle: Some(handle),
            outstanding: false,
            finished: None,
            steps: 0,
            step_cap: usize::MAX,
            failed_streak: 0,
            max_failed_streak: usize::MAX,
            last_probe: None,
            best_seen: None,
            abandoned: false,
        }
    }

    /// Bound the total probes this session may yield.
    pub fn set_step_cap(&mut self, cap: usize) {
        self.step_cap = cap.max(1);
    }

    /// Abandon after this many consecutive failed measurements.
    pub fn set_max_failed_streak(&mut self, cap: usize) {
        self.max_failed_streak = cap.max(1);
    }

    /// Probes yielded so far.
    pub fn steps(&self) -> usize {
        self.steps
    }

    pub fn is_abandoned(&self) -> bool {
        self.abandoned
    }

    /// Tear the explorer thread down (the Drop mechanism, but keeping
    /// the session queryable) and remember the best finite probe.
    fn abandon(&mut self) -> SessionStep {
        self.abandoned = true;
        let (dead_tx, _) = channel();
        self.tx_meas = dead_tx;
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
        SessionStep::Abandoned(self.best_seen.map(|(d, c)| SearchResult {
            best: c,
            best_duration: d,
            probes: self.steps,
        }))
    }

    /// Get the next step. Panics if a probe is outstanding (the caller
    /// must `report` the previous probe's duration first).
    pub fn next(&mut self) -> SessionStep {
        assert!(!self.outstanding, "previous probe not yet reported");
        if let Some(r) = self.finished {
            return SessionStep::Done(r);
        }
        if self.abandoned {
            return SessionStep::Abandoned(self.best_seen.map(|(d, c)| {
                SearchResult { best: c, best_duration: d, probes: self.steps }
            }));
        }
        if self.steps >= self.step_cap
            || self.failed_streak >= self.max_failed_streak
        {
            return self.abandon();
        }
        match self.rx_cand.recv() {
            Ok(c) => {
                self.outstanding = true;
                self.steps += 1;
                self.last_probe = Some(c);
                SessionStep::Probe(c)
            }
            Err(_) => {
                // explorer thread finished; collect its result
                let r = self
                    .handle
                    .take()
                    .expect("session already joined")
                    .join()
                    .expect("explorer thread panicked");
                self.finished = Some(r);
                SessionStep::Done(r)
            }
        }
    }

    /// Report the measured duration of the outstanding probe. A
    /// non-finite duration means the probe's execution died — it feeds
    /// the failed-streak guard instead of the best-seen fold.
    pub fn report(&mut self, duration: f64) {
        assert!(self.outstanding, "no probe outstanding");
        self.outstanding = false;
        if duration.is_finite() {
            self.failed_streak = 0;
            if let Some(c) = self.last_probe {
                if self.best_seen.map(|(d, _)| duration < d).unwrap_or(true) {
                    self.best_seen = Some((duration, c));
                }
            }
        } else {
            self.failed_streak += 1;
        }
        // a send failure means the explorer finished early; harmless
        let _ = self.tx_meas.send(duration);
    }

    pub fn is_finished(&self) -> bool {
        self.finished.is_some()
    }
}

impl Drop for SearchSession {
    fn drop(&mut self) {
        // Closing tx_meas unblocks the evaluator with an error; the
        // explorer thread then terminates with INFINITY measurements.
        let (dead_tx, _) = channel();
        self.tx_meas = dead_tx;
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simcluster::perfmodel::job_duration;

    #[test]
    fn session_replays_explorer_exactly() {
        // driving the session step-by-step must yield the same result as
        // calling the explorer synchronously
        let cfg = ExplorerConfig::default();
        let mut direct_eval =
            |c: ConfigIndex| job_duration(4, &c.to_config());
        let direct = Explorer::new(cfg.clone()).global_search(&mut direct_eval);

        let mut s = SearchSession::global(cfg);
        let result = loop {
            match s.next() {
                SessionStep::Probe(c) => {
                    s.report(job_duration(4, &c.to_config()))
                }
                SessionStep::Done(r) => break r,
                SessionStep::Abandoned(_) => unreachable!("no caps set"),
            }
        };
        assert_eq!(result.best, direct.best);
        assert_eq!(result.best_duration, direct.best_duration);
        assert_eq!(result.probes, direct.probes);
    }

    #[test]
    fn local_session_works() {
        let cfg = ExplorerConfig::default();
        let start = ConfigIndex([3, 3, 3, 3, 3, 1]);
        let mut s = SearchSession::local(cfg, start);
        let mut probes = 0;
        let r = loop {
            match s.next() {
                SessionStep::Probe(c) => {
                    probes += 1;
                    s.report(job_duration(2, &c.to_config()));
                }
                SessionStep::Done(r) => break r,
                SessionStep::Abandoned(_) => unreachable!("no caps set"),
            }
        };
        assert_eq!(probes, r.probes);
        assert!(r.best_duration <= job_duration(2, &start.to_config()));
    }

    #[test]
    fn done_is_idempotent() {
        let mut s = SearchSession::global(ExplorerConfig {
            global_budget: 3,
            local_budget: 2,
            min_improvement: 0.0,
        });
        let r1 = loop {
            match s.next() {
                SessionStep::Probe(_) => s.report(1.0),
                SessionStep::Done(r) => break r,
                SessionStep::Abandoned(_) => unreachable!("no caps set"),
            }
        };
        assert_eq!(s.next(), SessionStep::Done(r1));
        assert!(s.is_finished());
    }

    #[test]
    fn dropping_mid_search_does_not_hang() {
        let mut s = SearchSession::global(ExplorerConfig::default());
        match s.next() {
            SessionStep::Probe(_) => s.report(10.0),
            _ => {}
        }
        drop(s); // must not deadlock
    }

    #[test]
    fn step_cap_abandons_instead_of_livelocking() {
        let mut s = SearchSession::global(ExplorerConfig::default());
        s.set_step_cap(5);
        let mut probes = 0;
        let step = loop {
            match s.next() {
                SessionStep::Probe(c) => {
                    probes += 1;
                    s.report(job_duration(3, &c.to_config()));
                }
                other => break other,
            }
        };
        assert_eq!(probes, 5, "cap not enforced");
        match step {
            SessionStep::Abandoned(best) => {
                let b = best.expect("finite probes seen but no best");
                assert_eq!(b.probes, 5);
                assert!(b.best_duration.is_finite());
            }
            other => panic!("expected Abandoned, got {other:?}"),
        }
        assert!(s.is_abandoned());
        // abandonment is sticky and non-blocking
        assert!(matches!(s.next(), SessionStep::Abandoned(_)));
    }

    #[test]
    fn failed_streak_abandons_and_keeps_best_finite_probe() {
        let mut s = SearchSession::global(ExplorerConfig::default());
        s.set_max_failed_streak(3);
        // one good measurement, then every probe dies
        let mut reported = 0;
        let step = loop {
            match s.next() {
                SessionStep::Probe(_) => {
                    reported += 1;
                    s.report(if reported == 1 { 42.0 } else { f64::INFINITY });
                }
                other => break other,
            }
        };
        assert_eq!(reported, 4, "1 good + 3 failed before abandoning");
        match step {
            SessionStep::Abandoned(Some(b)) => {
                assert_eq!(b.best_duration, 42.0);
            }
            other => panic!("expected Abandoned(Some), got {other:?}"),
        }
    }

    #[test]
    fn all_failed_probes_abandon_with_no_best() {
        let mut s = SearchSession::global(ExplorerConfig::default());
        s.set_max_failed_streak(2);
        let step = loop {
            match s.next() {
                SessionStep::Probe(_) => s.report(f64::INFINITY),
                other => break other,
            }
        };
        assert_eq!(step, SessionStep::Abandoned(None));
    }

    #[test]
    #[should_panic(expected = "not yet reported")]
    fn double_next_without_report_panics() {
        let mut s = SearchSession::global(ExplorerConfig::default());
        let _ = s.next();
        let _ = s.next();
    }
}
