//! Tuning baselines: rule-of-thumb (the paper's "human administrator"),
//! exhaustive grid (the 100%-efficiency oracle), and random search.

use super::{ConfigEvaluator, SearchResult};
use crate::simcluster::config_space::ConfigIndex;
use crate::util::rng::Rng;

/// The published rule-of-thumb Spark sizing a competent administrator
/// applies without per-workload experimentation:
/// ~5 cores/executor, executors sized to fill the cluster with one
/// leave-out for the AM, executor memory = node_mem / executors_per_node
/// × 0.9, parallelism ≈ 2-3× total cores, compression on.
/// On our grid: mem 6144 (idx 3), cores 5 (idx 4), 12 executors (idx 3),
/// shuffle 128 (idx 3), parallelism 128 (idx 4), compression true.
pub fn rule_of_thumb() -> ConfigIndex {
    ConfigIndex([3, 4, 3, 3, 4, 1])
}

/// Exhaustive search over the full grid — defines the "fastest possible
/// tuning" the paper measures efficiency against. Returns the argmin and
/// the number of probes (the whole grid).
pub fn exhaustive(eval: &mut dyn ConfigEvaluator) -> SearchResult {
    let mut best = (f64::INFINITY, ConfigIndex([0; 6]));
    let mut probes = 0;
    for ci in ConfigIndex::enumerate_all() {
        let d = eval.measure(ci);
        probes += 1;
        if d < best.0 {
            best = (d, ci);
        }
    }
    SearchResult { best: best.1, best_duration: best.0, probes }
}

/// Uniform random search with a probe budget — the naive auto-tuner.
pub fn random_search(
    eval: &mut dyn ConfigEvaluator,
    budget: usize,
    rng: &mut Rng,
) -> SearchResult {
    let dims = ConfigIndex::dims();
    let mut best = (f64::INFINITY, ConfigIndex([0; 6]));
    for _ in 0..budget {
        let mut idx = [0usize; 6];
        for (d, i) in idx.iter_mut().enumerate() {
            *i = rng.range_usize(0, dims[d]);
        }
        let ci = ConfigIndex(idx);
        let dur = eval.measure(ci);
        if dur < best.0 {
            best = (dur, ci);
        }
    }
    SearchResult { best: best.1, best_duration: best.0, probes: budget }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simcluster::perfmodel::job_duration;

    #[test]
    fn exhaustive_is_true_argmin() {
        let mut eval = |c: ConfigIndex| job_duration(0, &c.to_config());
        let r = exhaustive(&mut eval);
        assert_eq!(r.probes, ConfigIndex::grid_size());
        // no grid point beats it
        for ci in ConfigIndex::enumerate_all() {
            assert!(job_duration(0, &ci.to_config()) >= r.best_duration - 1e-12);
        }
    }

    #[test]
    fn rule_of_thumb_is_valid_and_decent() {
        let rot = rule_of_thumb();
        let c = rot.to_config();
        assert_eq!(c.executor_cores, 5);
        assert!(c.compression);
        // decent but not optimal on a cpu-bound class
        let mut eval = |ci: ConfigIndex| job_duration(3, &ci.to_config());
        let oracle = exhaustive(&mut eval).best_duration;
        let rot_d = job_duration(3, &c);
        assert!(rot_d > oracle, "rule of thumb should not be optimal");
        assert!(rot_d < 6.0 * oracle, "but not catastrophic either");
    }

    #[test]
    fn random_search_improves_with_budget() {
        let mut rng_a = Rng::new(0);
        let mut rng_b = Rng::new(0);
        let mut e1 = |c: ConfigIndex| job_duration(2, &c.to_config());
        let mut e2 = |c: ConfigIndex| job_duration(2, &c.to_config());
        let small = random_search(&mut e1, 5, &mut rng_a);
        let large = random_search(&mut e2, 200, &mut rng_b);
        assert!(large.best_duration <= small.best_duration);
    }
}
