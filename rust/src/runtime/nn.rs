//! Typed wrappers over the NN artifacts: the LSTM WorkloadPredictor, the
//! MLP workload classifier (Fig 6's NN comparator), and the artifact-
//! backed pairwise-distance provider for DBSCAN.
//!
//! Rust owns all parameters (initialised here, updated by the `*_train`
//! artifacts — functional SGD steps compiled from jax.grad). The
//! forward-path artifacts contain the L1 pallas kernels; these wrappers
//! are exactly how "the paper's ML runs on the XLA runtime" while the
//! coordinator stays pure rust.
//!
//! Everything that executes artifacts is gated behind the
//! `runtime-artifacts` feature; without it this module exposes stubs
//! whose constructors fail (unreachable in practice, since the stub
//! `Runtime::load` already fails). [`SlotMap`] is pure rust and always
//! available.

use super::shapes;
use std::collections::BTreeMap;

// ---------------------------------------------------------------------------
// label <-> one-hot slot mapping (always available)
// ---------------------------------------------------------------------------

/// Workload labels are unbounded generated integers; the NN artifacts
/// have a fixed MAX_CLASSES-wide one-hot space. The slot map assigns
/// slots in first-seen order and recycles via modulo if ever exhausted
/// (documented degradation — 32 concurrent classes is ample here).
#[derive(Debug, Default, Clone)]
pub struct SlotMap {
    to_slot: BTreeMap<u32, usize>,
    to_label: Vec<u32>,
}

impl SlotMap {
    pub fn slot_of(&mut self, label: u32) -> usize {
        if let Some(&s) = self.to_slot.get(&label) {
            return s;
        }
        let s = if self.to_label.len() < shapes::MAX_CLASSES {
            self.to_label.push(label);
            self.to_label.len() - 1
        } else {
            (label as usize) % shapes::MAX_CLASSES
        };
        self.to_slot.insert(label, s);
        s
    }

    pub fn label_of(&self, slot: usize) -> Option<u32> {
        self.to_label.get(slot).copied()
    }

    pub fn len(&self) -> usize {
        self.to_label.len()
    }

    pub fn is_empty(&self) -> bool {
        self.to_label.is_empty()
    }
}

#[cfg(feature = "runtime-artifacts")]
pub use real::{ArtifactDistance, LstmPredictor, MlpClassifier, WelchAggregator};

#[cfg(not(feature = "runtime-artifacts"))]
pub use stubs::{ArtifactDistance, LstmPredictor, MlpClassifier, WelchAggregator};

/// "Artifact if available" pairwise-distance provider (ROADMAP): try to
/// load the PJRT runtime from the default artifact directory and back
/// the provider with the `pairwise_dist` pallas kernel; degrade to the
/// engine-parallel native implementation when the runtime is compiled
/// out (`runtime-artifacts` feature off) or the artifacts are missing
/// on disk. Callers that must know which path was taken can check
/// [`ArtifactDistance::new`] themselves; the coordinator just wants the
/// best available provider.
pub fn distance_provider(
    engine: crate::linalg::engine::Engine,
) -> Box<dyn crate::clustering::DistanceProvider> {
    let artifact = crate::runtime::Runtime::load(&crate::runtime::default_dir())
        .and_then(|rt| ArtifactDistance::new(&rt));
    match artifact {
        Ok(a) => Box::new(a),
        Err(_) => Box::new(crate::clustering::EngineDistance::new(engine)),
    }
}

// ---------------------------------------------------------------------------
// stubs (feature disabled)
// ---------------------------------------------------------------------------

#[cfg(not(feature = "runtime-artifacts"))]
mod stubs {
    use crate::clustering::DistanceProvider;
    use crate::linalg::Matrix;
    use crate::ml::Dataset;
    use crate::online::classifier::WindowClassifier;
    use crate::online::context::UNKNOWN;
    use crate::online::predictor::LabelPredictor;
    use crate::runtime::{shapes, Runtime};
    use crate::util::error::{Error, Result};
    use crate::workloadgen::Sample;

    fn disabled() -> Error {
        Error::msg(
            "NN artifacts unavailable: built without the \
             `runtime-artifacts` cargo feature",
        )
    }

    /// Stub LSTM predictor: unconstructible in practice (the stub
    /// `Runtime::load` fails before `new` can be reached).
    pub struct LstmPredictor {
        _priv: (),
    }

    impl LstmPredictor {
        pub fn new(_rt: &Runtime, _seed: u64) -> Result<LstmPredictor> {
            Err(disabled())
        }

        pub fn train_on_sequence(
            &self,
            _seq: &[u32],
            _epochs: usize,
            _lr: f64,
            _seed: u64,
        ) -> Result<f64> {
            Err(disabled())
        }
    }

    impl LabelPredictor for LstmPredictor {
        fn predict(&self, _history: &[u32], _horizon: usize) -> Option<u32> {
            None
        }
    }

    /// Stub MLP classifier.
    pub struct MlpClassifier {
        pub min_confidence: f64,
    }

    impl MlpClassifier {
        pub fn new(_rt: &Runtime, _seed: u64) -> Result<MlpClassifier> {
            Err(disabled())
        }

        pub fn fit(
            &self,
            _data: &Dataset,
            _epochs: usize,
            _lr: f64,
            _seed: u64,
        ) -> Result<f64> {
            Err(disabled())
        }

        pub fn logits(&self, _rows: &[Vec<f64>]) -> Result<Vec<Vec<f64>>> {
            Err(disabled())
        }
    }

    impl WindowClassifier for MlpClassifier {
        fn classify(&self, _features: &[f64]) -> u32 {
            UNKNOWN
        }
    }

    /// Stub batch aggregator.
    pub struct WelchAggregator {
        _priv: (),
    }

    impl WelchAggregator {
        pub fn new(_rt: &Runtime) -> Result<WelchAggregator> {
            Err(disabled())
        }

        pub fn window_size() -> usize {
            shapes::WELCH_SAMPLES
        }

        pub fn aggregate(
            &self,
            _samples: &[Sample],
            _start_index: u64,
        ) -> Result<Vec<crate::features::ObservationWindow>> {
            Err(disabled())
        }
    }

    /// Stub distance provider (never constructible; pairwise_sq is
    /// unreachable but must satisfy the trait).
    pub struct ArtifactDistance {
        _priv: (),
    }

    impl ArtifactDistance {
        pub fn new(_rt: &Runtime) -> Result<ArtifactDistance> {
            Err(disabled())
        }
    }

    impl DistanceProvider for ArtifactDistance {
        fn pairwise_sq(&self, rows: &Matrix) -> Vec<f64> {
            unreachable!("stub ArtifactDistance cannot be constructed: {rows:?}")
        }
    }
}

// ---------------------------------------------------------------------------
// real implementations (feature enabled)
// ---------------------------------------------------------------------------

#[cfg(feature = "runtime-artifacts")]
mod real {
    use super::SlotMap;
    use crate::linalg::Matrix;
    use crate::online::classifier::WindowClassifier;
    use crate::online::context::UNKNOWN;
    use crate::online::predictor::LabelPredictor;
    use crate::runtime::{
        literal_f32, literal_i32, literal_scalar, shapes, to_f64_vec,
        Artifact, Literal, Runtime,
    };
    use crate::util::error::Result;
    use crate::util::rng::Rng;
    use std::collections::BTreeMap;
    use std::rc::Rc;
    use std::sync::Mutex;

    fn init_matrix(
        rng: &mut Rng,
        rows: usize,
        cols: usize,
        scale: f64,
    ) -> Vec<f64> {
        (0..rows * cols).map(|_| rng.normal() * scale).collect()
    }

    // -----------------------------------------------------------------------
    // LSTM WorkloadPredictor
    // -----------------------------------------------------------------------

    /// LSTM predictor over workload-label sequences, running the
    /// `lstm_fwd` artifact for inference and `lstm_train` for BPTT+SGD
    /// training.
    pub struct LstmPredictor {
        fwd: Rc<Artifact>,
        train: Rc<Artifact>,
        /// wx [C,4H], wh [H,4H], b [4H], wo [H,C], bo [C] (row-major f64).
        params: Mutex<[Vec<f64>; 5]>,
        slots: Mutex<SlotMap>,
    }

    impl LstmPredictor {
        pub fn new(rt: &Runtime, seed: u64) -> Result<LstmPredictor> {
            let (c, h) = (shapes::MAX_CLASSES, shapes::LSTM_HIDDEN);
            let mut rng = Rng::new(seed);
            let params = [
                init_matrix(&mut rng, c, 4 * h, 0.25),
                init_matrix(&mut rng, h, 4 * h, 0.25),
                vec![0.0; 4 * h],
                init_matrix(&mut rng, h, c, 0.25),
                vec![0.0; c],
            ];
            Ok(LstmPredictor {
                fwd: rt.get("lstm_fwd")?,
                train: rt.get("lstm_train")?,
                params: Mutex::new(params),
                slots: Mutex::new(SlotMap::default()),
            })
        }

        fn param_literals(params: &[Vec<f64>; 5]) -> Result<Vec<Literal>> {
            let (c, h) =
                (shapes::MAX_CLASSES as i64, shapes::LSTM_HIDDEN as i64);
            Ok(vec![
                literal_f32(&params[0], &[c, 4 * h])?,
                literal_f32(&params[1], &[h, 4 * h])?,
                literal_f32(&params[2], &[4 * h])?,
                literal_f32(&params[3], &[h, c])?,
                literal_f32(&params[4], &[c])?,
            ])
        }

        /// One-hot encode the last LSTM_SEQ labels (left-padded with zeros).
        fn encode_seq(slots: &mut SlotMap, history: &[u32]) -> Vec<f64> {
            let (t, c) = (shapes::LSTM_SEQ, shapes::MAX_CLASSES);
            let mut seq = vec![0.0; t * c];
            let tail: Vec<u32> = history
                .iter()
                .rev()
                .take(t)
                .rev()
                .copied()
                .collect();
            let offset = t - tail.len();
            for (j, &label) in tail.iter().enumerate() {
                let s = slots.slot_of(label);
                seq[(offset + j) * c + s] = 1.0;
            }
            seq
        }

        fn forward_slot(&self, history: &[u32]) -> Result<Option<usize>> {
            if history.is_empty() {
                return Ok(None);
            }
            let params = self.params.lock().unwrap();
            let mut slots = self.slots.lock().unwrap();
            let seq = Self::encode_seq(&mut slots, history);
            let n_known = slots.len();
            drop(slots);
            let (t, c) = (shapes::LSTM_SEQ as i64, shapes::MAX_CLASSES as i64);
            let mut args = Self::param_literals(&params)?;
            args.push(literal_f32(&seq, &[1, t, c])?);
            let out = self.fwd.run(&args)?;
            let logits = to_f64_vec(&out[0])?;
            // argmax over the slots that map to known labels
            let best = logits[..n_known.max(1)]
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i);
            Ok(best)
        }

        /// Train on a label sequence: sliding windows of LSTM_SEQ + next
        /// label, shuffled into LSTM_BATCH minibatches. Returns final loss.
        pub fn train_on_sequence(
            &self,
            seq: &[u32],
            epochs: usize,
            lr: f64,
            seed: u64,
        ) -> Result<f64> {
            let (t, c, b) =
                (shapes::LSTM_SEQ, shapes::MAX_CLASSES, shapes::LSTM_BATCH);
            if seq.len() < 3 {
                return Ok(f64::NAN);
            }
            // build examples (input window, target slot)
            let mut slots = self.slots.lock().unwrap();
            let mut examples: Vec<(Vec<f64>, i32)> = Vec::new();
            for end in 1..seq.len() {
                let start = end.saturating_sub(t);
                let x = Self::encode_seq(&mut slots, &seq[start..end]);
                let y = slots.slot_of(seq[end]) as i32;
                examples.push((x, y));
            }
            drop(slots);

            let mut rng = Rng::new(seed);
            let mut last_loss = f64::NAN;
            for _ in 0..epochs {
                rng.shuffle(&mut examples);
                for chunk in examples.chunks(b) {
                    // pad the minibatch by repeating examples
                    let mut xs = Vec::with_capacity(b * t * c);
                    let mut ys = Vec::with_capacity(b);
                    for i in 0..b {
                        let (x, y) = &chunk[i % chunk.len()];
                        xs.extend_from_slice(x);
                        ys.push(*y);
                    }
                    let mut params = self.params.lock().unwrap();
                    let mut args = Self::param_literals(&params)?;
                    args.push(literal_f32(
                        &xs,
                        &[b as i64, t as i64, c as i64],
                    )?);
                    args.push(literal_i32(&ys, &[b as i64])?);
                    args.push(literal_scalar(lr));
                    let out = self.train.run(&args)?;
                    last_loss = to_f64_vec(&out[0])?[0];
                    for (k, p) in params.iter_mut().enumerate() {
                        *p = to_f64_vec(&out[k + 1])?;
                    }
                }
            }
            Ok(last_loss)
        }
    }

    impl LabelPredictor for LstmPredictor {
        fn predict(&self, history: &[u32], horizon: usize) -> Option<u32> {
            // roll the 1-step prediction forward for longer horizons
            let mut hist: Vec<u32> = history.to_vec();
            let mut out = None;
            for _ in 0..horizon.max(1) {
                let slot = self.forward_slot(&hist).ok()??;
                let label = self.slots.lock().unwrap().label_of(slot)?;
                hist.push(label);
                out = Some(label);
            }
            out
        }
    }

    // -----------------------------------------------------------------------
    // MLP workload classifier
    // -----------------------------------------------------------------------

    /// Two-layer MLP classifier over analytic windows, running `mlp_fwd` /
    /// `mlp_train`. Implements [`WindowClassifier`] so the on-line pipeline
    /// can use it interchangeably with the random forest.
    pub struct MlpClassifier {
        fwd: Rc<Artifact>,
        train: Rc<Artifact>,
        /// w1 [F,H], b1 [H], w2 [H,C], b2 [C]
        params: Mutex<[Vec<f64>; 4]>,
        slots: Mutex<SlotMap>,
        /// feature standardisation (mean, std) fitted at train time
        moments: Mutex<Vec<(f64, f64)>>,
        pub min_confidence: f64,
    }

    impl MlpClassifier {
        pub fn new(rt: &Runtime, seed: u64) -> Result<MlpClassifier> {
            let (f, h, c) = (
                shapes::MLP_FEATURES,
                shapes::MLP_HIDDEN,
                shapes::MAX_CLASSES,
            );
            let mut rng = Rng::new(seed);
            let params = [
                init_matrix(&mut rng, f, h, (2.0 / f as f64).sqrt()),
                vec![0.0; h],
                init_matrix(&mut rng, h, c, (2.0 / h as f64).sqrt()),
                vec![0.0; c],
            ];
            Ok(MlpClassifier {
                fwd: rt.get("mlp_fwd")?,
                train: rt.get("mlp_train")?,
                params: Mutex::new(params),
                slots: Mutex::new(SlotMap::default()),
                moments: Mutex::new(vec![(0.0, 1.0); shapes::MLP_FEATURES]),
                min_confidence: 0.6,
            })
        }

        fn param_literals(params: &[Vec<f64>; 4]) -> Result<Vec<Literal>> {
            let (f, h, c) = (
                shapes::MLP_FEATURES as i64,
                shapes::MLP_HIDDEN as i64,
                shapes::MAX_CLASSES as i64,
            );
            Ok(vec![
                literal_f32(&params[0], &[f, h])?,
                literal_f32(&params[1], &[h])?,
                literal_f32(&params[2], &[h, c])?,
                literal_f32(&params[3], &[c])?,
            ])
        }

        fn standardise(&self, row: &[f64]) -> Vec<f64> {
            let m = self.moments.lock().unwrap();
            row.iter()
                .zip(m.iter())
                .map(|(v, (mu, sd))| (v - mu) / sd)
                .collect()
        }

        /// Batch logits for up to MLP_BATCH rows (padded internally).
        pub fn logits(&self, rows: &[Vec<f64>]) -> Result<Vec<Vec<f64>>> {
            let (bsz, f, c) = (
                shapes::MLP_BATCH,
                shapes::MLP_FEATURES,
                shapes::MAX_CLASSES,
            );
            assert!(rows.len() <= bsz);
            let mut xs = vec![0.0; bsz * f];
            for (i, r) in rows.iter().enumerate() {
                let sr = self.standardise(r);
                xs[i * f..(i + 1) * f].copy_from_slice(&sr);
            }
            let params = self.params.lock().unwrap();
            let mut args = Self::param_literals(&params)?;
            args.push(literal_f32(&xs, &[bsz as i64, f as i64])?);
            let out = self.fwd.run(&args)?;
            let flat = to_f64_vec(&out[0])?;
            Ok(rows
                .iter()
                .enumerate()
                .map(|(i, _)| flat[i * c..(i + 1) * c].to_vec())
                .collect())
        }

        /// Train on a labelled dataset (epochs of shuffled minibatches).
        /// Fits the standardisation moments first. Returns final loss.
        pub fn fit(
            &self,
            data: &crate::ml::Dataset,
            epochs: usize,
            lr: f64,
            seed: u64,
        ) -> Result<f64> {
            assert_eq!(data.width(), shapes::MLP_FEATURES);
            *self.moments.lock().unwrap() = data.feature_moments();
            let (bsz, f) = (shapes::MLP_BATCH, shapes::MLP_FEATURES);
            let mut slots = self.slots.lock().unwrap();
            let examples: Vec<(Vec<f64>, i32)> = data
                .iter()
                .map(|(r, l)| (self.standardise(r), slots.slot_of(l) as i32))
                .collect();
            drop(slots);

            let mut order: Vec<usize> = (0..examples.len()).collect();
            let mut rng = Rng::new(seed);
            let mut last_loss = f64::NAN;
            for _ in 0..epochs {
                rng.shuffle(&mut order);
                for chunk in order.chunks(bsz) {
                    let mut xs = vec![0.0; bsz * f];
                    let mut ys = vec![0i32; bsz];
                    for i in 0..bsz {
                        let (x, y) = &examples[chunk[i % chunk.len()]];
                        xs[i * f..(i + 1) * f].copy_from_slice(x);
                        ys[i] = *y;
                    }
                    let mut params = self.params.lock().unwrap();
                    let mut args = Self::param_literals(&params)?;
                    args.push(literal_f32(&xs, &[bsz as i64, f as i64])?);
                    args.push(literal_i32(&ys, &[bsz as i64])?);
                    args.push(literal_scalar(lr));
                    let out = self.train.run(&args)?;
                    last_loss = to_f64_vec(&out[0])?[0];
                    for (k, p) in params.iter_mut().enumerate() {
                        *p = to_f64_vec(&out[k + 1])?;
                    }
                }
            }
            Ok(last_loss)
        }
    }

    impl WindowClassifier for MlpClassifier {
        fn classify(&self, features: &[f64]) -> u32 {
            let logits = match self.logits(&[features.to_vec()]) {
                Ok(l) => l,
                Err(_) => return UNKNOWN,
            };
            let row = &logits[0];
            let slots = self.slots.lock().unwrap();
            let n = slots.len().max(1);
            // softmax over known slots
            let max =
                row[..n].iter().copied().fold(f64::NEG_INFINITY, f64::max);
            let exps: Vec<f64> =
                row[..n].iter().map(|&l| (l - max).exp()).collect();
            let z: f64 = exps.iter().sum();
            let (best, share) = exps
                .iter()
                .enumerate()
                .map(|(i, &e)| (i, e / z))
                .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
                .unwrap();
            if share < self.min_confidence {
                return UNKNOWN;
            }
            slots.label_of(best).unwrap_or(UNKNOWN)
        }
    }

    // -----------------------------------------------------------------------
    // Artifact-backed batch window aggregation (welch_stats kernel)
    // -----------------------------------------------------------------------

    /// Batch observation-window aggregation through the `welch_stats`
    /// artifact (the L1 reduction kernel): the off-line analyser's re-scan
    /// of landed raw samples (Algorithm 2's batch ChangeDetector input)
    /// computes per-window mean/variance on the XLA runtime instead of the
    /// scalar loop. Numerically equivalent to
    /// `monitor::aggregate_samples` (asserted in tests and the integration
    /// suite).
    pub struct WelchAggregator {
        art: Rc<Artifact>,
    }

    impl WelchAggregator {
        pub fn new(rt: &Runtime) -> Result<WelchAggregator> {
            Ok(WelchAggregator { art: rt.get("welch_stats")? })
        }

        /// Window size this artifact was compiled for.
        pub fn window_size() -> usize {
            shapes::WELCH_SAMPLES
        }

        /// Aggregate raw samples into observation windows (window size fixed
        /// at WELCH_SAMPLES). Trailing partial window dropped, matching the
        /// native aggregator. Ground-truth tags are carried through from the
        /// samples exactly as `monitor::aggregate_samples` does.
        pub fn aggregate(
            &self,
            samples: &[crate::workloadgen::Sample],
            start_index: u64,
        ) -> Result<Vec<crate::features::ObservationWindow>> {
            use crate::features::NUM_FEATURES;
            let s = shapes::WELCH_SAMPLES;
            let wb = shapes::WELCH_WINDOWS;
            let f = NUM_FEATURES;
            let n_windows = samples.len() / s;
            let mut out = Vec::with_capacity(n_windows);

            let mut widx = 0usize;
            while widx < n_windows {
                let batch = (n_windows - widx).min(wb);
                // pack [wb, s, f]; unused windows zero-padded
                let mut xs = vec![0.0f64; wb * s * f];
                for w in 0..batch {
                    for si in 0..s {
                        let sample = &samples[(widx + w) * s + si];
                        for fi in 0..f {
                            xs[w * s * f + si * f + fi] =
                                sample.features[fi];
                        }
                    }
                }
                let lit = literal_f32(
                    &xs,
                    &[wb as i64, s as i64, f as i64],
                )?;
                let res = self.art.run(&[lit])?;
                let mean = to_f64_vec(&res[0])?;
                let var = to_f64_vec(&res[1])?;
                for w in 0..batch {
                    let chunk =
                        &samples[(widx + w) * s..(widx + w + 1) * s];
                    let tags: Vec<crate::workloadgen::TruthTag> =
                        chunk.iter().map(|x| x.truth).collect();
                    let mut mw = [0.0; NUM_FEATURES];
                    let mut vw = [0.0; NUM_FEATURES];
                    mw.copy_from_slice(&mean[w * f..(w + 1) * f]);
                    vw.copy_from_slice(&var[w * f..(w + 1) * f]);
                    out.push(crate::features::ObservationWindow {
                        index: start_index + (widx + w) as u64,
                        time: chunk.last().unwrap().time,
                        samples: s,
                        mean: mw,
                        var: vw,
                        truth: window_truth_of(&tags),
                    });
                }
                widx += batch;
            }
            Ok(out)
        }
    }

    /// Majority steady tag (mirrors the monitor's internal rule).
    fn window_truth_of(tags: &[crate::workloadgen::TruthTag]) -> Option<u32> {
        let mut counts = BTreeMap::new();
        for t in tags {
            if let crate::workloadgen::TruthTag::Steady(id) = t {
                *counts.entry(*id).or_insert(0usize) += 1;
            }
        }
        let (best, n) = counts.into_iter().max_by_key(|&(_, n)| n)?;
        if n * 2 > tags.len() {
            Some(best)
        } else {
            None
        }
    }

    // -----------------------------------------------------------------------
    // Artifact-backed distance provider for DBSCAN
    // -----------------------------------------------------------------------

    /// Pairwise-distance provider that routes the O(n²) distance matrix
    /// through the `pairwise_dist` artifact (the tiled pallas kernel),
    /// batching rows into DIST_N x DIST_N tiles.
    pub struct ArtifactDistance {
        art: Rc<Artifact>,
    }

    impl ArtifactDistance {
        pub fn new(rt: &Runtime) -> Result<ArtifactDistance> {
            Ok(ArtifactDistance { art: rt.get("pairwise_dist")? })
        }
    }

    impl crate::clustering::DistanceProvider for ArtifactDistance {
        fn pairwise_sq(&self, rows: &Matrix) -> Vec<f64> {
            let n = rows.n_rows();
            if n == 0 {
                return vec![];
            }
            let f = shapes::DIST_F;
            assert_eq!(
                rows.n_cols(),
                f,
                "ArtifactDistance expects analytic rows of width {f}"
            );
            let tile = shapes::DIST_N;
            let tiles = n.div_ceil(tile);
            // zero-padded row blocks
            let block_of = |ti: usize| -> Vec<f64> {
                let mut b = vec![0.0; tile * f];
                for i in 0..tile {
                    let r = ti * tile + i;
                    if r < n {
                        b[i * f..(i + 1) * f].copy_from_slice(rows.row(r));
                    }
                }
                b
            };
            let mut out = vec![0.0; n * n];
            for ti in 0..tiles {
                let bx = block_of(ti);
                let lx =
                    literal_f32(&bx, &[tile as i64, f as i64]).unwrap();
                for tj in ti..tiles {
                    let by = block_of(tj);
                    let ly =
                        literal_f32(&by, &[tile as i64, f as i64]).unwrap();
                    let res = self
                        .art
                        .run(&[&lx, &ly].map(|l| l.clone()))
                        .unwrap();
                    let d = to_f64_vec(&res[0]).unwrap();
                    for i in 0..tile {
                        let gi = ti * tile + i;
                        if gi >= n {
                            break;
                        }
                        for j in 0..tile {
                            let gj = tj * tile + j;
                            if gj >= n {
                                continue;
                            }
                            let v = d[i * tile + j];
                            out[gi * n + gj] = v;
                            out[gj * n + gi] = v;
                        }
                    }
                }
            }
            out
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;
        use crate::clustering::{DistanceProvider, NativeDistance};
        use crate::ml::Dataset;
        use std::path::Path;

        fn runtime() -> Runtime {
            Runtime::load(Path::new("artifacts"))
                .expect("run `make artifacts`")
        }

        #[test]
        fn lstm_learns_cyclic_pattern() {
            let rt = runtime();
            let p = LstmPredictor::new(&rt, 0).unwrap();
            let seq: Vec<u32> =
                (0..120).map(|i| [3u32, 8, 5][i % 3]).collect();
            let loss = p.train_on_sequence(&seq, 30, 0.5, 1).unwrap();
            assert!(loss < 0.35, "final loss {loss}");
            assert_eq!(p.predict(&[3, 8], 1), Some(5));
            assert_eq!(p.predict(&[8, 5], 1), Some(3));
            // multi-horizon rolls forward the cycle
            assert_eq!(p.predict(&[3, 8, 5], 3), Some(5));
        }

        #[test]
        fn mlp_classifies_separable_blobs() {
            let rt = runtime();
            let c = MlpClassifier::new(&rt, 0).unwrap();
            let mut rng = Rng::new(2);
            let mut d = Dataset::new();
            for _ in 0..150 {
                for (label, level) in [(10u32, 10.0), (20u32, 60.0)] {
                    let row: Vec<f64> = (0..shapes::MLP_FEATURES)
                        .map(|_| rng.normal_ms(level, 4.0))
                        .collect();
                    d.push(row, label);
                }
            }
            let loss = c.fit(&d, 12, 0.1, 3).unwrap();
            assert!(loss < 0.3, "loss {loss}");
            let a: Vec<f64> = vec![10.0; shapes::MLP_FEATURES];
            let b: Vec<f64> = vec![60.0; shapes::MLP_FEATURES];
            assert_eq!(c.classify(&a), 10);
            assert_eq!(c.classify(&b), 20);
        }

        #[test]
        fn artifact_distance_matches_native() {
            let rt = runtime();
            let ad = ArtifactDistance::new(&rt).unwrap();
            let mut rng = Rng::new(4);
            // n > DIST_N to exercise tiling
            let rows = Matrix::from_rows(
                &(0..300)
                    .map(|_| {
                        (0..shapes::DIST_F)
                            .map(|_| rng.range_f64(0.0, 50.0))
                            .collect()
                    })
                    .collect::<Vec<Vec<f64>>>(),
            );
            let got = ad.pairwise_sq(&rows);
            let want = NativeDistance.pairwise_sq(&rows);
            assert_eq!(got.len(), want.len());
            // f32 matmul formulation cancels catastrophically near zero:
            // absolute tolerance ~0.05 on norms of ~8e4 (eps^2 used by
            // DBSCAN is O(100), so this is 3 orders of magnitude below it)
            for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                assert!(
                    (g - w).abs() < 0.05 + 1e-2 * w,
                    "idx {i}: {g} vs {w}"
                );
            }
        }

        #[test]
        fn welch_aggregator_matches_native_monitor() {
            use crate::monitor::{aggregate_samples, MonitorConfig};
            use crate::workloadgen::{tour_schedule, Generator};
            let rt = runtime();
            let agg = WelchAggregator::new(&rt).unwrap();
            let mut g = Generator::with_default_config(5);
            // 200 windows of 32 samples: exercises multi-batch (> 64) path
            let trace = g.generate(&tour_schedule(3200, &[0, 2]));
            let native = aggregate_samples(
                &trace.samples,
                &MonitorConfig {
                    window_size: WelchAggregator::window_size(),
                },
            );
            let via = agg.aggregate(&trace.samples, 0).unwrap();
            assert_eq!(via.len(), native.len());
            for (a, b) in via.iter().zip(&native) {
                assert_eq!(a.index, b.index);
                assert_eq!(a.truth, b.truth);
                for i in 0..crate::features::NUM_FEATURES {
                    assert!(
                        (a.mean[i] - b.mean[i]).abs() < 1e-3,
                        "mean[{i}] {} vs {}",
                        a.mean[i],
                        b.mean[i]
                    );
                    assert!(
                        (a.var[i] - b.var[i]).abs() < 1e-2,
                        "var[{i}] {} vs {}",
                        a.var[i],
                        b.var[i]
                    );
                }
            }
        }

        #[test]
        fn lstm_empty_history_none() {
            let rt = runtime();
            let p = LstmPredictor::new(&rt, 0).unwrap();
            assert_eq!(p.predict(&[], 1), None);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slotmap_assigns_and_recycles() {
        let mut s = SlotMap::default();
        assert_eq!(s.slot_of(100), 0);
        assert_eq!(s.slot_of(7), 1);
        assert_eq!(s.slot_of(100), 0);
        assert_eq!(s.label_of(1), Some(7));
        assert_eq!(s.label_of(9), None);
    }

    #[test]
    fn distance_provider_degrades_to_native() {
        use crate::clustering::{DistanceProvider, NativeDistance};
        use crate::linalg::engine::Engine;
        use crate::linalg::Matrix;
        // without loadable artifacts (always true in the default build,
        // and true in artifact builds until `make artifacts` has run in
        // cwd) the provider must be the native fallback and agree with
        // NativeDistance exactly
        let provider = distance_provider(Engine::with_threads(2));
        let rows = Matrix::from_rows(&[
            vec![0.0, 0.0],
            vec![3.0, 4.0],
            vec![1.0, 1.0],
        ]);
        let got = provider.pairwise_sq(&rows);
        let want = NativeDistance.pairwise_sq(&rows);
        if crate::runtime::Runtime::load(&crate::runtime::default_dir()).is_err() {
            assert_eq!(got, want);
        } else {
            // artifact path live: f32 kernel, tolerance comparison
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() < 0.05 + 1e-2 * w, "{g} vs {w}");
            }
        }
    }
}
