//! Real PJRT runtime (feature `runtime-artifacts`): compiles and
//! executes the AOT HLO-text artifacts through the `xla` crate. This is
//! the only module in the crate that touches `xla`.

use super::{shapes, InputSpec};
use crate::util::error::{Error, Result};
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

/// Re-export so callers can name literal values without importing `xla`.
pub use xla::Literal;

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Error {
        Error::msg(e)
    }
}

/// A compiled artifact ready to execute.
pub struct Artifact {
    pub name: String,
    pub inputs: Vec<InputSpec>,
    exe: xla::PjRtLoadedExecutable,
}

impl Artifact {
    /// Execute with literal inputs; returns the flattened output tuple.
    pub fn run(&self, args: &[Literal]) -> Result<Vec<Literal>> {
        if args.len() != self.inputs.len() {
            return Err(Error::msg(format!(
                "{}: expected {} inputs, got {}",
                self.name,
                self.inputs.len(),
                args.len()
            )));
        }
        let mut out = self.exe.execute::<Literal>(args)?;
        let buf = out
            .pop()
            .and_then(|mut d| d.pop())
            .ok_or_else(|| Error::msg(format!("{}: empty result", self.name)))?;
        let lit = buf.to_literal_sync()?;
        // aot.py lowers with return_tuple=True: always a tuple
        Ok(lit.to_tuple()?)
    }
}

/// The artifact registry: PJRT client + every compiled model.
pub struct Runtime {
    pub client: xla::PjRtClient,
    artifacts: BTreeMap<String, Rc<Artifact>>,
    pub dir: PathBuf,
}

impl Runtime {
    /// Load and compile every artifact listed in `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Runtime> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
            Error::msg(format!(
                "reading {} — run `make artifacts` first: {e}",
                manifest_path.display()
            ))
        })?;
        let manifest = Json::parse(&text)?;

        // validate the shared shape constants
        let c = manifest.get("constants")?;
        let checks: [(&str, usize); 6] = [
            ("num_features", shapes::NUM_FEATURES),
            ("max_classes", shapes::MAX_CLASSES),
            ("dist_n", shapes::DIST_N),
            ("lstm_seq", shapes::LSTM_SEQ),
            ("mlp_batch", shapes::MLP_BATCH),
            ("mlp_features", shapes::MLP_FEATURES),
        ];
        for (key, want) in checks {
            let got = c.get(key)?.as_usize()?;
            if got != want {
                return Err(Error::msg(format!(
                    "manifest constant {key}={got} != rust {want}; \
                     re-run `make artifacts`"
                )));
            }
        }

        let client = xla::PjRtClient::cpu()?;
        let mut artifacts = BTreeMap::new();
        for (name, entry) in manifest.get("artifacts")?.as_obj()? {
            let file = dir.join(entry.get("file")?.as_str()?);
            let proto = xla::HloModuleProto::from_text_file(
                file.to_str().ok_or_else(|| Error::msg("non-utf8 path"))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;
            let inputs = entry
                .get("inputs")?
                .as_arr()?
                .iter()
                .map(|i| {
                    Ok(InputSpec {
                        dtype: i.get("dtype")?.as_str()?.to_string(),
                        shape: i
                            .get("shape")?
                            .as_arr()?
                            .iter()
                            .map(|d| d.as_usize())
                            .collect::<std::result::Result<_, _>>()?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            artifacts.insert(
                name.clone(),
                Rc::new(Artifact { name: name.clone(), inputs, exe }),
            );
        }
        Ok(Runtime { client, artifacts, dir: dir.to_path_buf() })
    }

    /// Default artifact directory: `$KERMIT_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        super::default_dir()
    }

    pub fn get(&self, name: &str) -> Result<Rc<Artifact>> {
        self.artifacts
            .get(name)
            .cloned()
            .ok_or_else(|| Error::msg(format!("unknown artifact '{name}'")))
    }

    pub fn names(&self) -> Vec<&str> {
        self.artifacts.keys().map(|s| s.as_str()).collect()
    }
}

// ---------------------------------------------------------------------------
// literal helpers
// ---------------------------------------------------------------------------

/// f32 literal of the given shape from an f64 slice (row-major).
pub fn literal_f32(values: &[f64], dims: &[i64]) -> Result<Literal> {
    let n: i64 = dims.iter().product();
    if n as usize != values.len() {
        return Err(Error::msg(format!(
            "literal_f32: {} values for shape {:?}",
            values.len(),
            dims
        )));
    }
    let v32: Vec<f32> = values.iter().map(|&x| x as f32).collect();
    Ok(Literal::vec1(&v32).reshape(dims)?)
}

/// i32 literal of the given shape.
pub fn literal_i32(values: &[i32], dims: &[i64]) -> Result<Literal> {
    Ok(Literal::vec1(values).reshape(dims)?)
}

/// scalar f32 literal.
pub fn literal_scalar(x: f64) -> Literal {
    Literal::scalar(x as f32)
}

/// Extract an f32 literal into f64s.
pub fn to_f64_vec(lit: &Literal) -> Result<Vec<f64>> {
    Ok(lit.to_vec::<f32>()?.into_iter().map(|x| x as f64).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn runtime() -> Runtime {
        // tests run from the crate root; artifacts/ must exist (make artifacts)
        Runtime::load(Path::new("artifacts")).expect(
            "artifacts missing — run `make artifacts` before cargo test",
        )
    }

    #[test]
    fn loads_all_artifacts() {
        let rt = runtime();
        let names = rt.names();
        for want in [
            "pairwise_dist", "welch_stats", "lstm_fwd", "lstm_train",
            "mlp_fwd", "mlp_train",
        ] {
            assert!(names.contains(&want), "missing {want}");
        }
    }

    #[test]
    fn pairwise_dist_matches_native() {
        let rt = runtime();
        let art = rt.get("pairwise_dist").unwrap();
        let n = shapes::DIST_N;
        let f = shapes::DIST_F;
        // deterministic pseudo-data
        let mut rng = crate::util::rng::Rng::new(7);
        let x: Vec<f64> = (0..n * f).map(|_| rng.range_f64(0.0, 10.0)).collect();
        let lx = literal_f32(&x, &[n as i64, f as i64]).unwrap();
        let ly = literal_f32(&x, &[n as i64, f as i64]).unwrap();
        let out = art.run(&[lx, ly]).unwrap();
        assert_eq!(out.len(), 1);
        let d = to_f64_vec(&out[0]).unwrap();
        assert_eq!(d.len(), n * n);
        // spot-check against native computation
        for (i, j) in [(0usize, 1usize), (5, 200), (255, 255), (17, 17)] {
            let want: f64 = (0..f)
                .map(|k| {
                    let a = x[i * f + k];
                    let b = x[j * f + k];
                    (a - b) * (a - b)
                })
                .sum();
            let got = d[i * n + j];
            assert!(
                (got - want).abs() < 1e-2 * want.max(1.0),
                "({i},{j}): {got} vs {want}"
            );
        }
    }

    #[test]
    fn welch_stats_matches_native() {
        let rt = runtime();
        let art = rt.get("welch_stats").unwrap();
        let (w, s, f) = (
            shapes::WELCH_WINDOWS,
            shapes::WELCH_SAMPLES,
            shapes::NUM_FEATURES,
        );
        let mut rng = crate::util::rng::Rng::new(8);
        let x: Vec<f64> =
            (0..w * s * f).map(|_| rng.normal_ms(5.0, 2.0)).collect();
        let lx = literal_f32(&x, &[w as i64, s as i64, f as i64]).unwrap();
        let out = art.run(&[lx]).unwrap();
        assert_eq!(out.len(), 2);
        let mean = to_f64_vec(&out[0]).unwrap();
        let var = to_f64_vec(&out[1]).unwrap();
        // native check for window 3, feature 2
        let (wi, fi) = (3usize, 2usize);
        let col: Vec<f64> =
            (0..s).map(|si| x[wi * s * f + si * f + fi]).collect();
        let m = crate::stats::mean(&col);
        let v = crate::stats::variance(&col);
        assert!((mean[wi * f + fi] - m).abs() < 1e-4, "mean");
        assert!((var[wi * f + fi] - v).abs() < 1e-3, "var");
    }

    #[test]
    fn wrong_arity_rejected() {
        let rt = runtime();
        let art = rt.get("pairwise_dist").unwrap();
        assert!(art.run(&[]).is_err());
    }
}
