//! API-compatible PJRT runtime stubs (default build, feature
//! `runtime-artifacts` disabled): `Runtime::load` always errors with a
//! clear message, so every artifact-dependent bench/test/example takes
//! its "artifacts not available — skipped" path, and the crate compiles
//! without the `xla` dependency.

use super::InputSpec;
use crate::util::error::{Error, Result};
use std::path::{Path, PathBuf};
use std::rc::Rc;

const DISABLED: &str = "PJRT runtime disabled: this binary was built \
without the `runtime-artifacts` cargo feature (see rust/Cargo.toml)";

fn disabled() -> Error {
    Error::msg(DISABLED)
}

/// Placeholder literal value (never materialised: `Runtime::load`
/// always fails first).
#[derive(Debug, Clone)]
pub struct Literal;

/// Placeholder artifact. Unconstructible outside this module, and the
/// module never constructs one.
pub struct Artifact {
    pub name: String,
    pub inputs: Vec<InputSpec>,
}

impl Artifact {
    pub fn run(&self, _args: &[Literal]) -> Result<Vec<Literal>> {
        Err(disabled())
    }
}

/// Placeholder runtime: loading always fails.
pub struct Runtime {
    _priv: (),
}

impl Runtime {
    pub fn load(_dir: &Path) -> Result<Runtime> {
        Err(disabled())
    }

    /// Default artifact directory: `$KERMIT_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        super::default_dir()
    }

    pub fn get(&self, _name: &str) -> Result<Rc<Artifact>> {
        Err(disabled())
    }

    pub fn names(&self) -> Vec<&str> {
        Vec::new()
    }
}

pub fn literal_f32(_values: &[f64], _dims: &[i64]) -> Result<Literal> {
    Err(disabled())
}

pub fn literal_i32(_values: &[i32], _dims: &[i64]) -> Result<Literal> {
    Err(disabled())
}

pub fn literal_scalar(_x: f64) -> Literal {
    Literal
}

pub fn to_f64_vec(_lit: &Literal) -> Result<Vec<f64>> {
    Err(disabled())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_fails_with_clear_message() {
        let e = Runtime::load(Path::new("artifacts")).err().unwrap();
        assert!(e.to_string().contains("runtime-artifacts"));
    }
}
