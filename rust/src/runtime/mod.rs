//! PJRT runtime: loads the AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the request path.
//!
//! The real implementation (`pjrt` module) is the only place the `xla`
//! crate is touched, and it is gated behind the `runtime-artifacts`
//! cargo feature so the default build needs neither the `xla` dependency
//! nor compiled XLA artifacts. Without the feature, this module exposes
//! API-compatible stubs whose loaders return a clear error — every bench
//! and test that needs artifacts already treats `Runtime::load` failure
//! as "skip", so the whole crate builds and tests green on a bare
//! toolchain. Python never runs at serve time either way: `make
//! artifacts` is a build step, after which the rust binary is
//! self-contained.
//!
//! Interchange format is HLO **text** — the image's xla_extension 0.5.1
//! rejects jax≥0.5's 64-bit-id serialized protos; the text parser
//! reassigns ids (see /opt/xla-example/README.md).

pub mod nn;

#[cfg(feature = "runtime-artifacts")]
mod pjrt;
#[cfg(feature = "runtime-artifacts")]
pub use pjrt::{
    literal_f32, literal_i32, literal_scalar, to_f64_vec, Artifact, Literal,
    Runtime,
};

#[cfg(not(feature = "runtime-artifacts"))]
mod stub;
#[cfg(not(feature = "runtime-artifacts"))]
pub use stub::{
    literal_f32, literal_i32, literal_scalar, to_f64_vec, Artifact, Literal,
    Runtime,
};

use std::path::PathBuf;

/// Shape constants shared with the python layer (mirrors
/// `python/compile/shapes.py`; validated against the manifest at load).
pub mod shapes {
    pub const NUM_FEATURES: usize = 16;
    pub const ANALYTIC_FEATURES: usize = 2 * NUM_FEATURES;
    pub const MAX_CLASSES: usize = 32;
    pub const DIST_N: usize = 256;
    pub const DIST_F: usize = ANALYTIC_FEATURES;
    pub const LSTM_HIDDEN: usize = 64;
    pub const LSTM_SEQ: usize = 16;
    pub const LSTM_BATCH: usize = 32;
    pub const MLP_FEATURES: usize = ANALYTIC_FEATURES;
    pub const MLP_HIDDEN: usize = 64;
    pub const MLP_BATCH: usize = 256;
    pub const WELCH_WINDOWS: usize = 64;
    pub const WELCH_SAMPLES: usize = 32;
}

/// Declared input spec of an artifact (from the manifest).
#[derive(Debug, Clone, PartialEq)]
pub struct InputSpec {
    pub dtype: String,
    pub shape: Vec<usize>,
}

/// Default artifact directory: `$KERMIT_ARTIFACTS` or `./artifacts`.
pub fn default_dir() -> PathBuf {
    std::env::var("KERMIT_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}
