//! Append-only write-ahead log of knowledge-plane mutations between
//! snapshots.
//!
//! Frame layout (all little-endian):
//!
//! ```text
//! [u32 payload_len][u64 seq][u64 checksum][payload bytes]
//! ```
//!
//! `checksum = fnv1a64(seq_le ++ payload)` — a bit flip in either the
//! sequence number or the record body is caught. The payload is the
//! compact JSON encoding of one [`WalRecord`] (records are small and
//! rare relative to measurements; debuggability wins over bytes here —
//! snapshots carry the bulk and use the binary codec).
//!
//! Torn-tail contract: records are appended strictly sequentially, so
//! the first frame that fails its length or checksum marks the end of
//! trustworthy data — everything from that offset on is truncated and
//! reported (`torn = true`). Recovery then continues with the valid
//! prefix ("zero loss up to the WAL tail").

use super::fnv1a64;
use crate::knowledge::workload_db::{entry_from_json, entry_to_json};
use crate::knowledge::WorkloadEntry;
use crate::simcluster::config_space::ConfigIndex;
use crate::util::error::{Error, Result};
use crate::util::json::Json;
use std::io::Write;
use std::path::Path;

/// One durable knowledge-plane mutation.
#[derive(Debug, Clone, PartialEq)]
pub enum WalRecord {
    /// A workload discovered (or restored): the full entry.
    Insert(Box<WorkloadEntry>),
    /// An optimum stored for `label` (Algorithm 1's "Update WorkloadDB
    /// with J_i^o"); `duration` present when the search measured it.
    Optimum {
        label: u32,
        config: ConfigIndex,
        duration: Option<f64>,
    },
    /// `label` quarantined (poison detector or integrity audit).
    Quarantine { label: u32 },
    /// `label` marked drifting: optimum trust revoked. The refreshed
    /// characterization is NOT logged (it is derivable from live
    /// traffic and only affects match distances); the trust flags are
    /// what recovery must preserve.
    Drift { label: u32 },
    /// A probe measurement fed to `label`'s search session. Replay is
    /// a state no-op (sessions are in-memory); logged so a restarted
    /// plane's operator can account for every paid probe.
    Measurement { label: u32, duration: f64 },
}

impl WalRecord {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        match self {
            WalRecord::Insert(e) => {
                j.set("t", Json::Str("insert".into()))
                    .set("entry", entry_to_json(e));
            }
            WalRecord::Optimum { label, config, duration } => {
                j.set("t", Json::Str("optimum".into()))
                    .set("label", Json::Num(*label as f64))
                    .set(
                        "config",
                        Json::Arr(
                            config
                                .0
                                .iter()
                                .map(|&i| Json::Num(i as f64))
                                .collect(),
                        ),
                    )
                    .set(
                        "duration",
                        match duration {
                            Some(d) => Json::Num(*d),
                            None => Json::Null,
                        },
                    );
            }
            WalRecord::Quarantine { label } => {
                j.set("t", Json::Str("quarantine".into()))
                    .set("label", Json::Num(*label as f64));
            }
            WalRecord::Drift { label } => {
                j.set("t", Json::Str("drift".into()))
                    .set("label", Json::Num(*label as f64));
            }
            WalRecord::Measurement { label, duration } => {
                j.set("t", Json::Str("measurement".into()))
                    .set("label", Json::Num(*label as f64))
                    .set("duration", Json::Num(*duration));
            }
        }
        j
    }

    pub fn from_json(j: &Json) -> Result<WalRecord> {
        let t = j.get("t")?.as_str()?;
        let label = |j: &Json| -> Result<u32> {
            Ok(j.get("label")?.as_usize()? as u32)
        };
        match t {
            "insert" => {
                let e = entry_from_json(j.get("entry")?)?;
                Ok(WalRecord::Insert(Box::new(e)))
            }
            "optimum" => {
                let v = j.get("config")?.f64s()?;
                if v.len() != 6 {
                    return Err(Error::persist(
                        "optimum record config is not 6-dimensional",
                    ));
                }
                let mut idx = [0usize; 6];
                for (d, x) in v.iter().enumerate() {
                    idx[d] = *x as usize;
                }
                let duration = match j.get("duration")? {
                    Json::Null => None,
                    n => Some(n.as_f64()?),
                };
                Ok(WalRecord::Optimum {
                    label: label(j)?,
                    config: ConfigIndex(idx),
                    duration,
                })
            }
            "quarantine" => Ok(WalRecord::Quarantine { label: label(j)? }),
            "drift" => Ok(WalRecord::Drift { label: label(j)? }),
            "measurement" => Ok(WalRecord::Measurement {
                label: label(j)?,
                duration: j.get("duration")?.as_f64()?,
            }),
            other => {
                Err(Error::persist(format!("unknown WAL record '{other}'")))
            }
        }
    }
}

const FRAME_HEADER: usize = 4 + 8 + 8;

/// Serialize one frame.
pub fn encode_frame(seq: u64, record: &WalRecord) -> Vec<u8> {
    let payload = record.to_json().encode().into_bytes();
    let seq_le = seq.to_le_bytes();
    let mut hashed = Vec::with_capacity(8 + payload.len());
    hashed.extend_from_slice(&seq_le);
    hashed.extend_from_slice(&payload);
    let mut out = Vec::with_capacity(FRAME_HEADER + payload.len());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&seq_le);
    out.extend_from_slice(&fnv1a64(&hashed).to_le_bytes());
    out.extend_from_slice(&payload);
    out
}

/// Append one frame to the WAL file at `path`, fsyncing so the record
/// survives a crash immediately after this call returns.
pub fn append_frame(path: &Path, seq: u64, record: &WalRecord) -> Result<()> {
    let mut f = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(path)?;
    f.write_all(&encode_frame(seq, record))?;
    f.sync_all()?;
    Ok(())
}

/// Result of scanning one WAL file.
#[derive(Debug, Clone, Default)]
pub struct WalScan {
    /// Valid records in append order, with their sequence numbers.
    pub records: Vec<(u64, WalRecord)>,
    /// Byte length of the valid prefix.
    pub valid_bytes: usize,
    /// True when the file ended in a torn / corrupt frame.
    pub torn: bool,
}

/// Decode every valid frame in `bytes`, stopping at the first torn or
/// checksum-failing frame (everything after it is untrustworthy — the
/// log is strictly sequential).
pub fn scan_frames(bytes: &[u8]) -> WalScan {
    let mut out = WalScan::default();
    let mut pos = 0usize;
    while pos < bytes.len() {
        let Some(header) = bytes.get(pos..pos + FRAME_HEADER) else {
            out.torn = true;
            break;
        };
        let mut u32le = [0u8; 4];
        u32le.copy_from_slice(&header[0..4]);
        let len = u32::from_le_bytes(u32le) as usize;
        let mut u64le = [0u8; 8];
        u64le.copy_from_slice(&header[4..12]);
        let seq = u64::from_le_bytes(u64le);
        u64le.copy_from_slice(&header[12..20]);
        let checksum = u64::from_le_bytes(u64le);
        let start = pos + FRAME_HEADER;
        let Some(payload) = start
            .checked_add(len)
            .filter(|&e| e <= bytes.len())
            .map(|e| &bytes[start..e])
        else {
            out.torn = true;
            break;
        };
        let mut hashed = Vec::with_capacity(8 + len);
        hashed.extend_from_slice(&seq.to_le_bytes());
        hashed.extend_from_slice(payload);
        if fnv1a64(&hashed) != checksum {
            out.torn = true;
            break;
        }
        let parsed = std::str::from_utf8(payload)
            .ok()
            .and_then(|s| Json::parse(s).ok())
            .and_then(|j| WalRecord::from_json(&j).ok());
        let Some(record) = parsed else {
            out.torn = true;
            break;
        };
        out.records.push((seq, record));
        pos = start + len;
        out.valid_bytes = pos;
    }
    out
}

/// Scan a WAL file; when the tail is torn, truncate the file in place
/// to the valid prefix (the repair is what lets the *next* appends go
/// to a clean log instead of hiding behind garbage forever).
pub fn recover_wal(path: &Path) -> Result<WalScan> {
    let bytes = match std::fs::read(path) {
        Ok(b) => b,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            return Ok(WalScan::default())
        }
        Err(e) => return Err(e.into()),
    };
    let scan = scan_frames(&bytes);
    if scan.torn {
        let f = std::fs::OpenOptions::new().write(true).open(path)?;
        f.set_len(scan.valid_bytes as u64)?;
        f.sync_all()?;
    }
    Ok(scan)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::knowledge::{Characterization, WorkloadDb};

    fn entry() -> WorkloadEntry {
        let rows = vec![vec![1.0, 2.0], vec![1.5, 2.5]];
        let mut db = WorkloadDb::new();
        let l = db.insert_new(
            Characterization::from_vec_rows(&rows),
            vec![1.25, 2.25],
            2,
            false,
        );
        db.set_optimal_measured(l, ConfigIndex([1, 2, 3, 0, 1, 0]), 12.5);
        db.get(l).unwrap().clone()
    }

    fn records() -> Vec<WalRecord> {
        vec![
            WalRecord::Insert(Box::new(entry())),
            WalRecord::Optimum {
                label: 0,
                config: ConfigIndex([1, 2, 3, 0, 1, 0]),
                duration: Some(12.5),
            },
            WalRecord::Optimum {
                label: 3,
                config: ConfigIndex([0, 0, 0, 0, 0, 0]),
                duration: None,
            },
            WalRecord::Quarantine { label: 3 },
            WalRecord::Drift { label: 0 },
            WalRecord::Measurement { label: 0, duration: 99.25 },
        ]
    }

    #[test]
    fn records_roundtrip_json() {
        for r in records() {
            let back = WalRecord::from_json(&r.to_json()).unwrap();
            assert_eq!(back, r);
        }
    }

    #[test]
    fn frames_roundtrip_in_order() {
        let mut bytes = Vec::new();
        for (i, r) in records().iter().enumerate() {
            bytes.extend_from_slice(&encode_frame(i as u64 + 10, r));
        }
        let scan = scan_frames(&bytes);
        assert!(!scan.torn);
        assert_eq!(scan.valid_bytes, bytes.len());
        assert_eq!(scan.records.len(), records().len());
        for (i, (seq, r)) in scan.records.iter().enumerate() {
            assert_eq!(*seq, i as u64 + 10);
            assert_eq!(r, &records()[i]);
        }
    }

    #[test]
    fn torn_tail_keeps_the_valid_prefix() {
        let rs = records();
        let mut bytes = Vec::new();
        let mut cut_at = 0usize;
        for (i, r) in rs.iter().enumerate() {
            if i == rs.len() - 1 {
                cut_at = bytes.len();
            }
            bytes.extend_from_slice(&encode_frame(i as u64, r));
        }
        // tear mid-way through the last frame
        for torn_len in [cut_at + 1, cut_at + FRAME_HEADER + 2] {
            let scan = scan_frames(&bytes[..torn_len]);
            assert!(scan.torn, "torn at {torn_len}");
            assert_eq!(scan.records.len(), rs.len() - 1);
            assert_eq!(scan.valid_bytes, cut_at);
        }
    }

    #[test]
    fn mid_log_bit_flip_truncates_from_there() {
        let rs = records();
        let mut bytes = Vec::new();
        let mut second_at = 0usize;
        for (i, r) in rs.iter().enumerate() {
            if i == 1 {
                second_at = bytes.len();
            }
            bytes.extend_from_slice(&encode_frame(i as u64, r));
        }
        bytes[second_at + FRAME_HEADER + 3] ^= 0x40;
        let scan = scan_frames(&bytes);
        assert!(scan.torn);
        // only the record before the corruption survives
        assert_eq!(scan.records.len(), 1);
        assert_eq!(scan.valid_bytes, second_at);
    }

    #[test]
    fn recover_truncates_the_file_in_place() {
        let dir = std::env::temp_dir().join("kermit_wal_recover_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("wal-000001.log");
        std::fs::remove_file(&path).ok();
        for (i, r) in records().iter().enumerate() {
            append_frame(&path, i as u64, r).unwrap();
        }
        let full = std::fs::metadata(&path).unwrap().len();
        // tear 5 bytes off the tail
        let f = std::fs::OpenOptions::new().write(true).open(&path).unwrap();
        f.set_len(full - 5).unwrap();
        drop(f);
        let scan = recover_wal(&path).unwrap();
        assert!(scan.torn);
        assert_eq!(scan.records.len(), records().len() - 1);
        // repaired: a second scan is clean and appends continue
        let scan2 = recover_wal(&path).unwrap();
        assert!(!scan2.torn);
        assert_eq!(scan2.records.len(), records().len() - 1);
        append_frame(&path, 77, &records()[0]).unwrap();
        let scan3 = recover_wal(&path).unwrap();
        assert!(!scan3.torn);
        assert_eq!(scan3.records.last().unwrap().0, 77);
        // a missing file scans empty (fresh store)
        let none = recover_wal(&dir.join("wal-000009.log")).unwrap();
        assert!(none.records.is_empty() && !none.torn);
        std::fs::remove_dir_all(&dir).ok();
    }
}
