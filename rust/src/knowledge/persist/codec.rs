//! Pluggable snapshot codecs. Both codecs serialize the same
//! deterministic `Json` tree (`WorkloadDb::to_json`), so a store can
//! switch formats between generations and recovery still reads every
//! retained file — the envelope records which codec wrote each one.

use crate::util::error::{Error, Result};
use crate::util::json::Json;

/// A snapshot payload codec: `Json` tree <-> bytes.
pub trait SnapshotCodec: Send + Sync {
    /// One-byte format id recorded in the snapshot envelope.
    fn id(&self) -> u8;
    /// Human-readable name (reports, bench meta).
    fn name(&self) -> &'static str;
    fn encode(&self, value: &Json) -> Vec<u8>;
    fn decode(&self, bytes: &[u8]) -> Result<Json>;
}

/// Debug-friendly codec: pretty-printed JSON text. Slower and larger,
/// but a snapshot file opens in any editor.
#[derive(Debug, Clone, Copy, Default)]
pub struct JsonCodec;

impl SnapshotCodec for JsonCodec {
    fn id(&self) -> u8 {
        b'J'
    }

    fn name(&self) -> &'static str {
        "json"
    }

    fn encode(&self, value: &Json) -> Vec<u8> {
        value.encode_pretty().into_bytes()
    }

    fn decode(&self, bytes: &[u8]) -> Result<Json> {
        let text = std::str::from_utf8(bytes)
            .map_err(|_| Error::persist("json payload is not utf-8"))?;
        Ok(Json::parse(text)?)
    }
}

// Binary type tags — self-describing: every value carries its tag, so
// a decoder needs no schema and skew-tolerant migration stays possible.
const T_NULL: u8 = 0x00;
const T_FALSE: u8 = 0x01;
const T_TRUE: u8 = 0x02;
const T_NUM: u8 = 0x03;
const T_STR: u8 = 0x04;
const T_ARR: u8 = 0x05;
const T_OBJ: u8 = 0x06;

/// Compact self-describing binary codec: tag byte + little-endian
/// lengths + raw f64 bits. Roughly 3-4x smaller than pretty JSON for a
/// WorkloadDb payload (mostly f64 arrays) and no float formatting /
/// parsing on the hot recovery path.
#[derive(Debug, Clone, Copy, Default)]
pub struct BinaryCodec;

impl BinaryCodec {
    fn write(value: &Json, out: &mut Vec<u8>) {
        match value {
            Json::Null => out.push(T_NULL),
            Json::Bool(false) => out.push(T_FALSE),
            Json::Bool(true) => out.push(T_TRUE),
            Json::Num(x) => {
                out.push(T_NUM);
                out.extend_from_slice(&x.to_le_bytes());
            }
            Json::Str(s) => {
                out.push(T_STR);
                out.extend_from_slice(&(s.len() as u32).to_le_bytes());
                out.extend_from_slice(s.as_bytes());
            }
            Json::Arr(v) => {
                out.push(T_ARR);
                out.extend_from_slice(&(v.len() as u32).to_le_bytes());
                for x in v {
                    Self::write(x, out);
                }
            }
            Json::Obj(m) => {
                out.push(T_OBJ);
                out.extend_from_slice(&(m.len() as u32).to_le_bytes());
                for (k, v) in m {
                    out.extend_from_slice(
                        &(k.len() as u32).to_le_bytes(),
                    );
                    out.extend_from_slice(k.as_bytes());
                    Self::write(v, out);
                }
            }
        }
    }

    fn read(bytes: &[u8], pos: &mut usize) -> Result<Json> {
        let tag = *bytes
            .get(*pos)
            .ok_or_else(|| Error::persist("binary payload truncated"))?;
        *pos += 1;
        match tag {
            T_NULL => Ok(Json::Null),
            T_FALSE => Ok(Json::Bool(false)),
            T_TRUE => Ok(Json::Bool(true)),
            T_NUM => {
                let raw = Self::take(bytes, pos, 8)?;
                let mut le = [0u8; 8];
                le.copy_from_slice(raw);
                Ok(Json::Num(f64::from_le_bytes(le)))
            }
            T_STR => {
                let s = Self::read_str(bytes, pos)?;
                Ok(Json::Str(s))
            }
            T_ARR => {
                let n = Self::read_len(bytes, pos)?;
                let mut v = Vec::with_capacity(n.min(1 << 16));
                for _ in 0..n {
                    v.push(Self::read(bytes, pos)?);
                }
                Ok(Json::Arr(v))
            }
            T_OBJ => {
                let n = Self::read_len(bytes, pos)?;
                let mut m = std::collections::BTreeMap::new();
                for _ in 0..n {
                    let k = Self::read_str(bytes, pos)?;
                    let v = Self::read(bytes, pos)?;
                    m.insert(k, v);
                }
                Ok(Json::Obj(m))
            }
            other => Err(Error::persist(format!(
                "unknown binary tag 0x{other:02x}"
            ))),
        }
    }

    fn take<'a>(
        bytes: &'a [u8],
        pos: &mut usize,
        n: usize,
    ) -> Result<&'a [u8]> {
        let end = pos
            .checked_add(n)
            .filter(|&e| e <= bytes.len())
            .ok_or_else(|| Error::persist("binary payload truncated"))?;
        let out = &bytes[*pos..end];
        *pos = end;
        Ok(out)
    }

    fn read_len(bytes: &[u8], pos: &mut usize) -> Result<usize> {
        let raw = Self::take(bytes, pos, 4)?;
        let mut le = [0u8; 4];
        le.copy_from_slice(raw);
        Ok(u32::from_le_bytes(le) as usize)
    }

    fn read_str(bytes: &[u8], pos: &mut usize) -> Result<String> {
        let n = Self::read_len(bytes, pos)?;
        let raw = Self::take(bytes, pos, n)?;
        String::from_utf8(raw.to_vec())
            .map_err(|_| Error::persist("binary string is not utf-8"))
    }
}

impl SnapshotCodec for BinaryCodec {
    fn id(&self) -> u8 {
        b'B'
    }

    fn name(&self) -> &'static str {
        "binary"
    }

    fn encode(&self, value: &Json) -> Vec<u8> {
        let mut out = Vec::new();
        Self::write(value, &mut out);
        out
    }

    fn decode(&self, bytes: &[u8]) -> Result<Json> {
        let mut pos = 0usize;
        let v = Self::read(bytes, &mut pos)?;
        if pos != bytes.len() {
            return Err(Error::persist(format!(
                "binary payload has {} trailing bytes",
                bytes.len() - pos
            )));
        }
        Ok(v)
    }
}

/// Resolve a codec by its envelope id (recovery reads whatever format
/// each retained generation was written with).
pub fn codec_for(id: u8) -> Option<Box<dyn SnapshotCodec>> {
    match id {
        b'J' => Some(Box::new(JsonCodec)),
        b'B' => Some(Box::new(BinaryCodec)),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Json {
        let mut inner = Json::obj();
        inner
            .set("pi", Json::Num(3.25))
            .set("neg", Json::Num(-0.0))
            .set("big", Json::Num(1e300))
            .set("label", Json::Num(7.0));
        let mut root = Json::obj();
        root.set("null", Json::Null)
            .set("yes", Json::Bool(true))
            .set("no", Json::Bool(false))
            .set("name", Json::Str("wörk\nload".into()))
            .set("xs", Json::from_f64_slice(&[1.0, 2.5, -3.0]))
            .set("nested", Json::Arr(vec![inner, Json::Null]));
        root
    }

    #[test]
    fn both_codecs_roundtrip_the_same_tree() {
        let v = sample();
        for codec in [
            Box::new(JsonCodec) as Box<dyn SnapshotCodec>,
            Box::new(BinaryCodec),
        ] {
            let bytes = codec.encode(&v);
            let back = codec.decode(&bytes).unwrap();
            assert_eq!(back, v, "{} codec", codec.name());
            // deterministic: same tree → same bytes (the byte-stable
            // snapshot contract rides on this)
            assert_eq!(bytes, codec.encode(&v), "{}", codec.name());
        }
    }

    #[test]
    fn binary_is_smaller_than_json_for_numeric_payloads() {
        let v = Json::from_f64_slice(
            &(0..256).map(|i| i as f64 * 0.37).collect::<Vec<_>>(),
        );
        let jb = JsonCodec.encode(&v).len();
        let bb = BinaryCodec.encode(&v).len();
        assert!(bb < jb, "binary {bb} >= json {jb}");
    }

    #[test]
    fn binary_rejects_truncation_and_garbage() {
        let bytes = BinaryCodec.encode(&sample());
        for cut in [0, 1, 5, bytes.len() - 1] {
            assert!(
                BinaryCodec.decode(&bytes[..cut]).is_err(),
                "truncated at {cut} must not decode"
            );
        }
        assert!(BinaryCodec.decode(&[0xff, 0x00]).is_err());
        // trailing garbage is rejected (a short read is detected even
        // when the prefix happens to parse)
        let mut padded = bytes.clone();
        padded.push(0x00);
        assert!(BinaryCodec.decode(&padded).is_err());
    }

    #[test]
    fn codec_ids_resolve() {
        assert_eq!(codec_for(b'J').unwrap().name(), "json");
        assert_eq!(codec_for(b'B').unwrap().name(), "binary");
        assert!(codec_for(b'X').is_none());
    }

    #[test]
    fn binary_preserves_f64_bits_json_cannot() {
        // raw-bit fidelity is the binary codec's point: -0.0 survives
        let v = Json::Num(-0.0);
        let back = BinaryCodec.decode(&BinaryCodec.encode(&v)).unwrap();
        match back {
            Json::Num(x) => assert!(x == 0.0 && x.is_sign_negative()),
            other => panic!("unexpected {other:?}"),
        }
    }
}
