//! The versioned snapshot envelope and atomic writer.
//!
//! On-disk layout of one snapshot file (`snap-<gen>.kdb`):
//!
//! ```text
//! offset  size  field
//!      0     8  magic  b"KERMITKB"
//!      8     4  version (u32 LE) — envelope schema, migrated forward
//!     12     1  codec id (b'J' json / b'B' binary)
//!     13     3  reserved (zero)
//!     16     8  payload length (u64 LE)
//!     24     8  FNV-1a-64 checksum of the payload
//!     32     …  payload (codec-encoded shell)
//! ```
//!
//! The payload shell at [`SNAPSHOT_VERSION`] is
//! `{"schema": 2, "last_seq": N, "db": <WorkloadDb::to_json>}` — the
//! `last_seq` high-water mark is what makes WAL replay idempotent
//! (records already folded into the snapshot are skipped by sequence
//! number, so a crash between snapshot rename and WAL rotation can
//! never replay stale records over newer state).
//!
//! Migration: version 1 carried the bare `WorkloadDb` JSON with no
//! shell (and no sequence high-water mark — treated as 0); a file with
//! no magic at all is a legacy `WorkloadDb::save` text file (version
//! 0). Both are wrapped forward into the current shell on read, so
//! every pre-PR-7 DB file loads through this one code path.

use super::codec::{codec_for, SnapshotCodec};
use super::fnv1a64;
use crate::util::error::{Error, Result};
use crate::util::json::Json;
use std::io::Write;
use std::path::{Path, PathBuf};

/// Current envelope version.
pub const SNAPSHOT_VERSION: u32 = 2;

const MAGIC: &[u8; 8] = b"KERMITKB";
const HEADER_LEN: usize = 32;

/// A decoded snapshot: the version it was written at, the sequence
/// high-water mark, and the bare `WorkloadDb` JSON.
#[derive(Debug, Clone)]
pub struct SnapshotPayload {
    /// Envelope version found on disk (before migration).
    pub version: u32,
    /// Highest WAL sequence number folded into this snapshot.
    pub last_seq: u64,
    /// The `WorkloadDb::to_json` tree.
    pub db: Json,
}

/// Build the current-version payload shell.
pub fn make_shell(db_json: Json, last_seq: u64) -> Json {
    let mut shell = Json::obj();
    shell
        .set("schema", Json::Num(SNAPSHOT_VERSION as f64))
        .set("last_seq", Json::Num(last_seq as f64))
        .set("db", db_json);
    shell
}

/// Path of generation `g` inside `dir`.
pub fn snapshot_path(dir: &Path, generation: u64) -> PathBuf {
    dir.join(format!("snap-{generation:06}.kdb"))
}

/// Path of the WAL that collects records written *after* snapshot `g`.
pub fn wal_path(dir: &Path, generation: u64) -> PathBuf {
    dir.join(format!("wal-{generation:06}.log"))
}

/// List snapshot generations present in `dir`, ascending.
pub fn list_generations(dir: &Path) -> Vec<u64> {
    let mut gens: Vec<u64> = match std::fs::read_dir(dir) {
        Ok(rd) => rd
            .filter_map(|e| e.ok())
            .filter_map(|e| {
                let name = e.file_name().into_string().ok()?;
                let num = name
                    .strip_prefix("snap-")?
                    .strip_suffix(".kdb")?;
                num.parse::<u64>().ok()
            })
            .collect(),
        Err(_) => Vec::new(),
    };
    gens.sort_unstable();
    gens
}

/// Serialize the envelope bytes for `shell` (no I/O).
pub fn encode_snapshot(
    codec: &dyn SnapshotCodec,
    shell: &Json,
) -> Vec<u8> {
    let payload = codec.encode(shell);
    let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&SNAPSHOT_VERSION.to_le_bytes());
    out.push(codec.id());
    out.extend_from_slice(&[0u8; 3]);
    out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
    out.extend_from_slice(&fnv1a64(&payload).to_le_bytes());
    out.extend_from_slice(&payload);
    out
}

/// Atomically write `bytes` to `path`: write `<path>.tmp`, fsync the
/// file, rename over `path`, then fsync the directory (best-effort —
/// not every platform lets a directory be fsynced). A reader never
/// observes a half-written snapshot under a final name; a crash leaves
/// at worst a stale `.tmp` that recovery ignores.
pub fn write_atomic(path: &Path, bytes: &[u8]) -> Result<()> {
    let tmp = path.with_extension("kdb.tmp");
    {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(bytes)?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, path)?;
    if let Some(dir) = path.parent() {
        if let Ok(d) = std::fs::File::open(dir) {
            let _ = d.sync_all();
        }
    }
    Ok(())
}

/// Parse snapshot `bytes` (any supported version, including legacy
/// magic-less `WorkloadDb::save` JSON), verifying the checksum and
/// migrating old shells forward. This is the ONLY entry point for
/// reading persisted knowledge, so the version/migration guarantees
/// hold for every caller.
pub fn decode_snapshot(bytes: &[u8]) -> Result<SnapshotPayload> {
    if bytes.len() < HEADER_LEN || &bytes[0..8] != MAGIC {
        // legacy (version 0): a bare WorkloadDb::save JSON text file
        let text = std::str::from_utf8(bytes).map_err(|_| {
            Error::persist("no envelope magic and not utf-8 text")
        })?;
        let db = Json::parse(text).map_err(|e| {
            Error::persist(format!("legacy snapshot unparsable: {e}"))
        })?;
        db.get("next_label").map_err(|_| {
            Error::persist("legacy snapshot is not a WorkloadDb file")
        })?;
        return Ok(SnapshotPayload { version: 0, last_seq: 0, db });
    }
    let mut u32le = [0u8; 4];
    u32le.copy_from_slice(&bytes[8..12]);
    let version = u32::from_le_bytes(u32le);
    if version == 0 || version > SNAPSHOT_VERSION {
        return Err(Error::persist(format!(
            "snapshot version {version} unsupported (max \
             {SNAPSHOT_VERSION}) — refusing to guess"
        )));
    }
    let codec_id = bytes[12];
    let mut u64le = [0u8; 8];
    u64le.copy_from_slice(&bytes[16..24]);
    let payload_len = u64::from_le_bytes(u64le) as usize;
    u64le.copy_from_slice(&bytes[24..32]);
    let checksum = u64::from_le_bytes(u64le);
    let end = HEADER_LEN.checked_add(payload_len).ok_or_else(|| {
        Error::persist("snapshot header claims an absurd payload length")
    })?;
    let payload = bytes.get(HEADER_LEN..end).ok_or_else(|| {
        Error::persist(format!(
            "snapshot truncated: header claims {payload_len} \
             payload bytes, {} present",
            bytes.len() - HEADER_LEN
        ))
    })?;
    if bytes.len() != end {
        return Err(Error::persist("snapshot has trailing bytes"));
    }
    if fnv1a64(payload) != checksum {
        return Err(Error::persist(
            "snapshot checksum mismatch — refusing to serve corrupt \
             entries",
        ));
    }
    let codec = codec_for(codec_id).ok_or_else(|| {
        Error::persist(format!("unknown snapshot codec 0x{codec_id:02x}"))
    })?;
    let shell = codec.decode(payload)?;
    migrate(version, shell)
}

/// Read + decode one snapshot file.
pub fn read_snapshot(path: &Path) -> Result<SnapshotPayload> {
    let bytes = std::fs::read(path)?;
    decode_snapshot(&bytes)
}

/// Migrate a decoded shell from `version` to the current schema.
fn migrate(version: u32, shell: Json) -> Result<SnapshotPayload> {
    match version {
        // v1: bare WorkloadDb JSON, no shell, no sequence watermark
        1 => Ok(SnapshotPayload { version, last_seq: 0, db: shell }),
        2 => {
            let last_seq = shell.get("last_seq")?.as_usize()? as u64;
            let db = shell.get("db")?.clone();
            Ok(SnapshotPayload { version, last_seq, db })
        }
        other => Err(Error::persist(format!(
            "no migration path from snapshot version {other}"
        ))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::knowledge::persist::codec::{BinaryCodec, JsonCodec};
    use crate::knowledge::{Characterization, WorkloadDb};
    use crate::util::error::ErrorKind;

    fn tiny_db() -> WorkloadDb {
        let mut db = WorkloadDb::new();
        let rows = vec![vec![1.0, 2.0], vec![1.2, 2.2]];
        db.insert_new(
            Characterization::from_vec_rows(&rows),
            vec![1.1, 2.1],
            2,
            false,
        );
        db
    }

    #[test]
    fn envelope_roundtrips_both_codecs() {
        let db = tiny_db();
        for codec in [
            Box::new(JsonCodec) as Box<dyn SnapshotCodec>,
            Box::new(BinaryCodec),
        ] {
            let shell = make_shell(db.to_json(), 41);
            let bytes = encode_snapshot(codec.as_ref(), &shell);
            let p = decode_snapshot(&bytes).unwrap();
            assert_eq!(p.version, SNAPSHOT_VERSION);
            assert_eq!(p.last_seq, 41);
            let back = WorkloadDb::from_json(&p.db).unwrap();
            assert_eq!(back.len(), 1);
        }
    }

    #[test]
    fn bit_flip_is_rejected_with_persist_kind() {
        let shell = make_shell(tiny_db().to_json(), 0);
        let mut bytes = encode_snapshot(&BinaryCodec, &shell);
        let k = HEADER_LEN + bytes.len() / 2;
        bytes[k] ^= 0x10;
        let e = decode_snapshot(&bytes).unwrap_err();
        assert_eq!(e.kind(), ErrorKind::Persist);
        assert!(e.to_string().contains("checksum"), "{e}");
    }

    #[test]
    fn torn_write_is_rejected() {
        let shell = make_shell(tiny_db().to_json(), 0);
        let bytes = encode_snapshot(&JsonCodec, &shell);
        // a torn header and a torn payload both fail loudly
        assert!(decode_snapshot(&bytes[..16]).is_err());
        let e = decode_snapshot(&bytes[..bytes.len() - 3]).unwrap_err();
        assert!(e.to_string().contains("truncated"), "{e}");
    }

    #[test]
    fn future_versions_are_refused_not_guessed() {
        let shell = make_shell(tiny_db().to_json(), 0);
        let mut bytes = encode_snapshot(&BinaryCodec, &shell);
        bytes[8..12].copy_from_slice(&99u32.to_le_bytes());
        let e = decode_snapshot(&bytes).unwrap_err();
        assert!(e.to_string().contains("version 99"), "{e}");
    }

    #[test]
    fn legacy_bare_json_migrates_forward() {
        // a pre-PR-7 WorkloadDb::save file: no magic, no envelope
        let text = tiny_db().to_json().encode_pretty();
        let p = decode_snapshot(text.as_bytes()).unwrap();
        assert_eq!(p.version, 0);
        assert_eq!(p.last_seq, 0);
        assert_eq!(WorkloadDb::from_json(&p.db).unwrap().len(), 1);
        // but arbitrary JSON is not mistaken for a DB
        assert!(decode_snapshot(b"{\"x\": 1}").is_err());
        assert!(decode_snapshot(&[0xfe, 0xff, 0x00]).is_err());
    }

    #[test]
    fn atomic_write_leaves_no_tmp_behind() {
        let dir = std::env::temp_dir().join("kermit_snap_atomic_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = snapshot_path(&dir, 1);
        let shell = make_shell(tiny_db().to_json(), 7);
        let bytes = encode_snapshot(&BinaryCodec, &shell);
        write_atomic(&path, &bytes).unwrap();
        assert_eq!(read_snapshot(&path).unwrap().last_seq, 7);
        assert!(!path.with_extension("kdb.tmp").exists());
        assert_eq!(list_generations(&dir), vec![1]);
        std::fs::remove_dir_all(&dir).ok();
    }
}
