//! [`KnowledgeStore`]: snapshot generations + active WAL + recovery.
//!
//! Lifecycle: [`KnowledgeStore::open`] scans the store directory,
//! loads the newest snapshot that verifies (falling back a generation
//! on checksum / parse failure), replays every retained WAL record
//! whose sequence number is beyond the snapshot's high-water mark, and
//! repairs torn WAL tails in place. From then on the owner appends
//! journaled mutations ([`KnowledgeStore::append_all`]) and
//! periodically folds the DB into a new generation
//! ([`KnowledgeStore::snapshot`]).
//!
//! The [`IoFaultPlan`] is how the chaos lab drives the crash-
//! consistency proof: each armed fault fires exactly once at its
//! injection point (torn snapshot write, payload bit flip, crash
//! before / after the rename, torn WAL tail at process death), and the
//! recovery assertions in `chaoslab::persistence` hold for every one.

use super::codec::SnapshotCodec;
use super::snapshot::{
    self, encode_snapshot, list_generations, make_shell, read_snapshot,
    snapshot_path, wal_path, SNAPSHOT_VERSION,
};
use super::wal::{append_frame, recover_wal, WalRecord};
use crate::knowledge::WorkloadDb;
use crate::util::error::Result;
use crate::util::json::Json;
use std::path::{Path, PathBuf};

/// Snapshot generations retained on disk. Older generations (and
/// their WALs) are pruned after each successful snapshot; the retained
/// window is what checksum-failure fallback can reach.
pub const RETAINED_GENERATIONS: usize = 3;

/// Seeded one-shot I/O faults. Each armed fault fires at most once at
/// its injection point and then disarms, so a scenario can stage
/// "corrupt exactly the next snapshot" deterministically.
#[derive(Debug, Clone, Default)]
pub struct IoFaultPlan {
    /// Truncate the next snapshot's bytes to this length before they
    /// reach disk (a torn write that survived the rename — e.g. lost
    /// sectors on a powercut after the metadata journal committed).
    pub snapshot_torn_write_at: Option<usize>,
    /// Flip one bit of the next snapshot's payload at this offset
    /// (modulo payload length): silent media corruption.
    pub snapshot_bit_flip_at: Option<usize>,
    /// Next snapshot: write the temp file, then crash before the
    /// rename (the final name never appears).
    pub crash_before_rename: bool,
    /// Next snapshot: rename succeeds, then crash before the WAL is
    /// rotated or old generations pruned — the window the `last_seq`
    /// high-water mark exists for.
    pub crash_after_rename: bool,
    /// At [`KnowledgeStore::simulate_crash`]: chop this many bytes off
    /// the active WAL's tail (an append torn mid-frame by the crash).
    pub wal_torn_tail_bytes: Option<u64>,
    /// At the next [`KnowledgeStore::open`] (via
    /// [`KnowledgeStore::open_with_faults`]): truncate the newest
    /// snapshot's bytes to this length after reading them — a short
    /// read the decoder must refuse.
    pub short_read_at: Option<usize>,
}

/// Counters for the persistence hot path.
#[derive(Debug, Clone, Copy, Default)]
pub struct PersistStats {
    pub snapshots_written: u64,
    pub snapshot_bytes: u64,
    pub wal_records_appended: u64,
    pub wal_bytes: u64,
}

impl PersistStats {
    /// Bridge the persistence counters into a telemetry registry under
    /// `kermit_persist_*`.
    pub fn export_metrics(&self, reg: &crate::obs::Registry) {
        let c = |name: &str, help: &str, v: u64| {
            reg.counter(name, help, &[]).set_total(v);
        };
        c(
            "kermit_persist_snapshots_written_total",
            "Knowledge snapshots rotated to disk.",
            self.snapshots_written,
        );
        c(
            "kermit_persist_snapshot_bytes_total",
            "Bytes written across all snapshots.",
            self.snapshot_bytes,
        );
        c(
            "kermit_persist_wal_records_total",
            "Records appended to the write-ahead log.",
            self.wal_records_appended,
        );
        c(
            "kermit_persist_wal_bytes_total",
            "Bytes appended to the write-ahead log.",
            self.wal_bytes,
        );
    }
}

/// What recovery did — every decision auditable, and the numbers the
/// chaos-lab guarantees are asserted against.
#[derive(Debug, Clone, Default)]
pub struct RecoveryReport {
    /// Generation whose snapshot seeded the DB (None: started empty).
    pub generation_loaded: Option<u64>,
    /// Snapshot files rejected (checksum / parse / short read) while
    /// falling back to an older generation.
    pub snapshots_rejected: u64,
    /// Envelope version the loaded snapshot was written at, when older
    /// than [`SNAPSHOT_VERSION`] (it was migrated forward on read).
    pub migrated_from: Option<u32>,
    /// WAL records applied on top of the snapshot.
    pub wal_records_replayed: u64,
    /// True when at least one WAL ended in a torn frame (the tail was
    /// truncated in place and everything before it kept).
    pub wal_torn_tail: bool,
    /// Optimum records among the replayed set.
    pub optima_recovered: u64,
    /// Quarantine records among the replayed set.
    pub quarantined_recovered: u64,
}

impl RecoveryReport {
    /// Bridge the recovery decisions into a telemetry registry under
    /// `kermit_persist_recovery_*` (how the last open fell back).
    pub fn export_metrics(&self, reg: &crate::obs::Registry) {
        reg.counter(
            "kermit_persist_recovery_snapshots_rejected_total",
            "Snapshot files rejected while falling back on recovery.",
            &[],
        )
        .set_total(self.snapshots_rejected);
        reg.counter(
            "kermit_persist_recovery_wal_replayed_total",
            "WAL records applied on top of the recovered snapshot.",
            &[],
        )
        .set_total(self.wal_records_replayed);
        reg.gauge(
            "kermit_persist_recovery_torn_tail",
            "1 when the last recovery truncated a torn WAL tail.",
            &[],
        )
        .set(if self.wal_torn_tail { 1.0 } else { 0.0 });
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set(
            "generation_loaded",
            match self.generation_loaded {
                Some(g) => Json::Num(g as f64),
                None => Json::Null,
            },
        )
        .set("snapshots_rejected", Json::Num(self.snapshots_rejected as f64))
        .set(
            "migrated_from",
            match self.migrated_from {
                Some(v) => Json::Num(v as f64),
                None => Json::Null,
            },
        )
        .set(
            "wal_records_replayed",
            Json::Num(self.wal_records_replayed as f64),
        )
        .set("wal_torn_tail", Json::Bool(self.wal_torn_tail))
        .set("optima_recovered", Json::Num(self.optima_recovered as f64))
        .set(
            "quarantined_recovered",
            Json::Num(self.quarantined_recovered as f64),
        );
        o
    }
}

/// The durable knowledge store: one directory of snapshot generations
/// plus the active WAL.
pub struct KnowledgeStore {
    dir: PathBuf,
    codec: Box<dyn SnapshotCodec>,
    /// Newest snapshot generation on disk (0 = none yet). The active
    /// WAL is `wal-<generation>.log`; the next snapshot is
    /// `generation + 1`.
    generation: u64,
    /// Next WAL sequence number to assign (starts at 1; 0 is the
    /// "nothing folded" high-water mark of an empty store).
    seq: u64,
    /// Armed chaos faults (default: none).
    pub faults: IoFaultPlan,
    pub stats: PersistStats,
}

impl KnowledgeStore {
    /// Open (or create) the store at `dir`, recovering the DB.
    pub fn open(
        dir: &Path,
        codec: Box<dyn SnapshotCodec>,
    ) -> Result<(KnowledgeStore, WorkloadDb, RecoveryReport)> {
        Self::open_with_faults(dir, codec, IoFaultPlan::default())
    }

    /// Open with pre-armed read-path faults (chaos lab).
    pub fn open_with_faults(
        dir: &Path,
        codec: Box<dyn SnapshotCodec>,
        mut faults: IoFaultPlan,
    ) -> Result<(KnowledgeStore, WorkloadDb, RecoveryReport)> {
        std::fs::create_dir_all(dir)?;
        remove_stale_tmp(dir);
        let mut report = RecoveryReport::default();

        // newest verifying snapshot wins; corrupt ones fall back
        let gens = list_generations(dir);
        let mut db = WorkloadDb::new();
        let mut last_seq = 0u64;
        for &g in gens.iter().rev() {
            let payload = {
                let read = if let Some(cut) = faults.short_read_at.take()
                {
                    std::fs::read(snapshot_path(dir, g)).map(|b| {
                        let cut = cut.min(b.len());
                        b[..cut].to_vec()
                    })
                } else {
                    std::fs::read(snapshot_path(dir, g))
                };
                read.map_err(crate::util::error::Error::from)
                    .and_then(|b| snapshot::decode_snapshot(&b))
            };
            match payload.and_then(|p| {
                let db = WorkloadDb::from_json(&p.db)?;
                Ok((p, db))
            }) {
                Ok((p, loaded)) => {
                    db = loaded;
                    last_seq = p.last_seq;
                    report.generation_loaded = Some(g);
                    if p.version < SNAPSHOT_VERSION {
                        report.migrated_from = Some(p.version);
                    }
                    break;
                }
                Err(_) => {
                    report.snapshots_rejected += 1;
                }
            }
        }

        // replay every retained WAL record beyond the high-water mark,
        // ascending; sequence numbers are globally monotone, so this is
        // correct even when the newest snapshot was rejected
        let mut max_seq = last_seq;
        for g in list_wal_generations(dir) {
            let scan = recover_wal(&wal_path(dir, g))?;
            if scan.torn {
                report.wal_torn_tail = true;
            }
            for (seq, record) in scan.records {
                max_seq = max_seq.max(seq);
                if seq <= last_seq {
                    continue;
                }
                report.wal_records_replayed += 1;
                match record {
                    WalRecord::Insert(e) => db.restore_entry(*e),
                    WalRecord::Optimum { label, config, duration } => {
                        if db.get(label).is_some() {
                            report.optima_recovered += 1;
                            match duration {
                                Some(d) => db
                                    .set_optimal_measured(label, config, d),
                                None => {
                                    db.set_optimal_config(label, config)
                                }
                            }
                        }
                    }
                    WalRecord::Quarantine { label } => {
                        if db.quarantine(label) {
                            report.quarantined_recovered += 1;
                        }
                    }
                    WalRecord::Drift { label } => {
                        if let Some(e) = db.get_mut(label) {
                            e.is_drifting = true;
                            e.optimal_config_found = false;
                        }
                    }
                    // sessions are in-memory; the record is an audit
                    // trail of paid probes, not replayable state
                    WalRecord::Measurement { .. } => {}
                }
            }
        }

        let store = KnowledgeStore {
            dir: dir.to_path_buf(),
            codec,
            generation: gens.last().copied().unwrap_or(0),
            seq: max_seq + 1,
            faults,
            stats: PersistStats::default(),
        };
        Ok((store, db, report))
    }

    /// Directory this store persists into.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Newest snapshot generation on disk.
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Next sequence number (diagnostics).
    pub fn next_seq(&self) -> u64 {
        self.seq
    }

    /// Append one journaled mutation to the active WAL (fsynced: once
    /// this returns, the record survives any crash).
    pub fn append(&mut self, record: &WalRecord) -> Result<()> {
        let path = wal_path(&self.dir, self.generation);
        append_frame(&path, self.seq, record)?;
        self.seq += 1;
        self.stats.wal_records_appended += 1;
        self.stats.wal_bytes +=
            super::wal::encode_frame(self.seq - 1, record).len() as u64;
        Ok(())
    }

    /// Append a batch (a drained journal) in order.
    pub fn append_all(&mut self, records: &[WalRecord]) -> Result<()> {
        for r in records {
            self.append(r)?;
        }
        Ok(())
    }

    /// Fold `db` into a new snapshot generation, rotate the WAL, and
    /// prune generations beyond [`RETAINED_GENERATIONS`]. Returns the
    /// generation written. Armed snapshot faults fire here.
    pub fn snapshot(&mut self, db: &WorkloadDb) -> Result<u64> {
        let next_gen = self.generation + 1;
        let shell = make_shell(db.to_json(), self.seq - 1);
        let mut bytes = encode_snapshot(self.codec.as_ref(), &shell);

        if let Some(k) = self.faults.snapshot_bit_flip_at.take() {
            let payload_len = bytes.len().saturating_sub(32).max(1);
            let at = 32 + k % payload_len;
            if at < bytes.len() {
                bytes[at] ^= 0x04;
            }
        }
        if let Some(cut) = self.faults.snapshot_torn_write_at.take() {
            bytes.truncate(cut.min(bytes.len()));
        }

        let path = snapshot_path(&self.dir, next_gen);
        if std::mem::take(&mut self.faults.crash_before_rename) {
            // temp file written, power lost before the rename: the
            // final name never appears; recovery ignores the .tmp
            let tmp = path.with_extension("kdb.tmp");
            std::fs::write(&tmp, &bytes)?;
            return Ok(next_gen);
        }
        snapshot::write_atomic(&path, &bytes)?;
        self.stats.snapshots_written += 1;
        self.stats.snapshot_bytes += bytes.len() as u64;
        if std::mem::take(&mut self.faults.crash_after_rename) {
            // crash between rename and rotation: the store keeps
            // appending to the OLD WAL and nothing is pruned — the
            // snapshot's last_seq high-water mark makes the overlap
            // harmless at the next recovery
            return Ok(next_gen);
        }
        self.generation = next_gen;
        self.prune();
        Ok(next_gen)
    }

    /// Drop the store as a crash would: no final snapshot, no clean
    /// rotation — and, when armed, a torn tail on the active WAL.
    pub fn simulate_crash(mut self) {
        if let Some(chop) = self.faults.wal_torn_tail_bytes.take() {
            let path = wal_path(&self.dir, self.generation);
            if let Ok(meta) = std::fs::metadata(&path) {
                let keep = meta.len().saturating_sub(chop);
                if let Ok(f) =
                    std::fs::OpenOptions::new().write(true).open(&path)
                {
                    let _ = f.set_len(keep);
                    let _ = f.sync_all();
                }
            }
        }
    }

    fn prune(&self) {
        let gens = list_generations(&self.dir);
        if gens.len() <= RETAINED_GENERATIONS {
            return;
        }
        for &g in &gens[..gens.len() - RETAINED_GENERATIONS] {
            let _ = std::fs::remove_file(snapshot_path(&self.dir, g));
            let _ = std::fs::remove_file(wal_path(&self.dir, g));
        }
    }

    /// Export `db` as one self-contained snapshot file (federated
    /// knowledge: a fresh cluster imports a peer's learned optima and
    /// starts warm).
    pub fn export(
        db: &WorkloadDb,
        path: &Path,
        codec: &dyn SnapshotCodec,
    ) -> Result<()> {
        let shell = make_shell(db.to_json(), 0);
        snapshot::write_atomic(path, &encode_snapshot(codec, &shell))
    }

    /// Import a snapshot file written by [`export`](Self::export) — or
    /// any supported envelope version, including a legacy bare
    /// `WorkloadDb::save` JSON file.
    pub fn import(path: &Path) -> Result<WorkloadDb> {
        let p = read_snapshot(path)?;
        Ok(WorkloadDb::from_json(&p.db)?)
    }
}

fn remove_stale_tmp(dir: &Path) {
    if let Ok(rd) = std::fs::read_dir(dir) {
        for e in rd.filter_map(|e| e.ok()) {
            if e.file_name().to_string_lossy().ends_with(".tmp") {
                let _ = std::fs::remove_file(e.path());
            }
        }
    }
}

fn list_wal_generations(dir: &Path) -> Vec<u64> {
    let mut gens: Vec<u64> = match std::fs::read_dir(dir) {
        Ok(rd) => rd
            .filter_map(|e| e.ok())
            .filter_map(|e| {
                let name = e.file_name().into_string().ok()?;
                name.strip_prefix("wal-")?
                    .strip_suffix(".log")?
                    .parse::<u64>()
                    .ok()
            })
            .collect(),
        Err(_) => Vec::new(),
    };
    gens.sort_unstable();
    gens
}

/// Deterministic digest of a DB's *durable* state: per label, the
/// fields the crash-safety contract guarantees (trust flags, config,
/// quarantine, measured optimum, lineage). `window_count` and the
/// characterization statistics are excluded — refreshes are not
/// journaled, by design — so pre-crash and post-recovery digests are
/// comparable byte-for-byte.
pub fn durable_digest(db: &WorkloadDb) -> Json {
    let rows = db
        .entries()
        .map(|e| {
            let mut o = Json::obj();
            o.set("label", Json::Num(e.label as f64))
                .set(
                    "optimal_config_found",
                    Json::Bool(e.optimal_config_found),
                )
                .set("quarantined", Json::Bool(e.quarantined))
                .set("synthetic", Json::Bool(e.synthetic))
                .set(
                    "config",
                    match e.config {
                        Some(ci) => Json::Arr(
                            ci.0.iter()
                                .map(|&i| Json::Num(i as f64))
                                .collect(),
                        ),
                        None => Json::Null,
                    },
                )
                .set(
                    "best_duration",
                    match e.best_duration {
                        Some(d) => Json::Num(d),
                        None => Json::Null,
                    },
                )
                .set(
                    "parents",
                    match e.parents {
                        Some((a, b)) => Json::from_f64_slice(&[
                            a as f64, b as f64,
                        ]),
                        None => Json::Null,
                    },
                );
            o
        })
        .collect();
    Json::Arr(rows)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::knowledge::persist::codec::{BinaryCodec, JsonCodec};
    use crate::knowledge::Characterization;
    use crate::simcluster::config_space::ConfigIndex;

    fn tmp_store(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("kermit_store_{name}"));
        std::fs::remove_dir_all(&dir).ok();
        dir
    }

    fn char_of(mean: f64) -> Characterization {
        let rows: Vec<Vec<f64>> = (0..4)
            .map(|i| vec![mean + (i % 2) as f64, 2.0 * mean])
            .collect();
        Characterization::from_vec_rows(&rows)
    }

    /// Drive a journaling DB + store through a few mutations.
    fn populate(db: &mut WorkloadDb, store: &mut KnowledgeStore) {
        db.enable_journal();
        let a = db.insert_new(char_of(1.0), vec![1.0, 2.0], 4, false);
        let b = db.insert_new(char_of(9.0), vec![9.0, 18.0], 4, false);
        db.set_optimal_measured(a, ConfigIndex([1, 2, 3, 0, 1, 0]), 11.0);
        db.set_optimal_config(b, ConfigIndex([0, 0, 1, 1, 0, 0]));
        db.quarantine(b);
        store.append_all(&db.take_journal()).unwrap();
    }

    #[test]
    fn wal_only_state_survives_reopen() {
        let dir = tmp_store("wal_only");
        let (mut store, mut db, report) =
            KnowledgeStore::open(&dir, Box::new(BinaryCodec)).unwrap();
        assert_eq!(report.generation_loaded, None);
        populate(&mut db, &mut store);
        let digest = durable_digest(&db);
        store.simulate_crash();

        let (_, back, report) =
            KnowledgeStore::open(&dir, Box::new(BinaryCodec)).unwrap();
        assert_eq!(report.generation_loaded, None);
        assert_eq!(report.wal_records_replayed, 5);
        assert_eq!(report.optima_recovered, 2);
        assert_eq!(report.quarantined_recovered, 1);
        assert_eq!(durable_digest(&back), digest);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn snapshot_then_wal_replays_only_the_tail() {
        let dir = tmp_store("snap_tail");
        let (mut store, mut db, _) =
            KnowledgeStore::open(&dir, Box::new(BinaryCodec)).unwrap();
        populate(&mut db, &mut store);
        assert_eq!(store.snapshot(&db).unwrap(), 1);
        // post-snapshot mutation lands in the rotated WAL
        let c = db.insert_new(char_of(5.0), vec![5.0, 10.0], 4, false);
        db.set_optimal_measured(c, ConfigIndex([2, 2, 2, 2, 2, 0]), 7.0);
        store.append_all(&db.take_journal()).unwrap();
        let digest = durable_digest(&db);
        store.simulate_crash();

        let (store2, back, report) =
            KnowledgeStore::open(&dir, Box::new(BinaryCodec)).unwrap();
        assert_eq!(report.generation_loaded, Some(1));
        // pre-snapshot records are already folded in: NOT replayed
        assert_eq!(report.wal_records_replayed, 2);
        assert_eq!(durable_digest(&back), digest);
        assert_eq!(store2.generation(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bit_flipped_snapshot_falls_back_a_generation() {
        let dir = tmp_store("bit_flip");
        let (mut store, mut db, _) =
            KnowledgeStore::open(&dir, Box::new(BinaryCodec)).unwrap();
        populate(&mut db, &mut store);
        store.snapshot(&db).unwrap(); // gen 1: clean
        let digest_gen1 = durable_digest(&db);
        let c = db.insert_new(char_of(5.0), vec![5.0, 10.0], 4, false);
        db.set_optimal_config(c, ConfigIndex([3, 3, 3, 3, 3, 0]));
        store.append_all(&db.take_journal()).unwrap();
        store.faults.snapshot_bit_flip_at = Some(17);
        store.snapshot(&db).unwrap(); // gen 2: corrupt payload
        store.simulate_crash();

        let (_, back, report) =
            KnowledgeStore::open(&dir, Box::new(BinaryCodec)).unwrap();
        assert_eq!(report.snapshots_rejected, 1);
        assert_eq!(report.generation_loaded, Some(1));
        // the WAL records between gen 1 and gen 2 are still replayed,
        // so nothing was lost despite the corrupt newest snapshot —
        // the digest must include label c's optimum
        assert_eq!(report.wal_records_replayed, 2);
        assert!(back.get(c).unwrap().optimal_config_found);
        assert_ne!(durable_digest(&back), digest_gen1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn crash_before_rename_is_invisible() {
        let dir = tmp_store("pre_rename");
        let (mut store, mut db, _) =
            KnowledgeStore::open(&dir, Box::new(JsonCodec)).unwrap();
        populate(&mut db, &mut store);
        let digest = durable_digest(&db);
        store.faults.crash_before_rename = true;
        store.snapshot(&db).unwrap();
        store.simulate_crash();

        let (_, back, report) =
            KnowledgeStore::open(&dir, Box::new(JsonCodec)).unwrap();
        // no snapshot ever appeared; the stale .tmp was swept; the WAL
        // alone reconstructs everything
        assert_eq!(report.generation_loaded, None);
        assert_eq!(report.snapshots_rejected, 0);
        assert_eq!(durable_digest(&back), digest);
        assert!(list_generations(&dir).is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn crash_after_rename_never_replays_stale_records() {
        let dir = tmp_store("post_rename");
        let (mut store, mut db, _) =
            KnowledgeStore::open(&dir, Box::new(BinaryCodec)).unwrap();
        populate(&mut db, &mut store);
        let a = 0u32;
        store.faults.crash_after_rename = true;
        store.snapshot(&db).unwrap(); // gen 1 exists; WAL NOT rotated
        // post-crash-window mutation appends to the OLD wal (gen 0)
        db.quarantine(a);
        store.append_all(&db.take_journal()).unwrap();
        let digest = durable_digest(&db);
        store.simulate_crash();

        let (_, back, report) =
            KnowledgeStore::open(&dir, Box::new(BinaryCodec)).unwrap();
        assert_eq!(report.generation_loaded, Some(1));
        // only the ONE record past the snapshot's high-water mark
        // replays; the five already-folded ones are skipped by seq
        assert_eq!(report.wal_records_replayed, 1);
        assert_eq!(report.quarantined_recovered, 1);
        assert_eq!(durable_digest(&back), digest);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn short_read_rejects_and_falls_back() {
        let dir = tmp_store("short_read");
        let (mut store, mut db, _) =
            KnowledgeStore::open(&dir, Box::new(BinaryCodec)).unwrap();
        populate(&mut db, &mut store);
        store.snapshot(&db).unwrap();
        let digest = durable_digest(&db);
        store.simulate_crash();

        let faults = IoFaultPlan {
            short_read_at: Some(40),
            ..IoFaultPlan::default()
        };
        let (_, back, report) = KnowledgeStore::open_with_faults(
            &dir,
            Box::new(BinaryCodec),
            faults,
        )
        .unwrap();
        // the truncated read of gen 1 is refused; with no older
        // generation the WAL alone rebuilds the state
        assert_eq!(report.snapshots_rejected, 1);
        assert_eq!(report.generation_loaded, None);
        assert_eq!(durable_digest(&back), digest);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn pruning_keeps_a_bounded_window() {
        let dir = tmp_store("prune");
        let (mut store, mut db, _) =
            KnowledgeStore::open(&dir, Box::new(BinaryCodec)).unwrap();
        populate(&mut db, &mut store);
        for _ in 0..5 {
            store.snapshot(&db).unwrap();
        }
        let gens = list_generations(&dir);
        assert_eq!(gens.len(), RETAINED_GENERATIONS);
        assert_eq!(gens.last(), Some(&5));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn export_import_roundtrips_and_reads_legacy() {
        let dir = tmp_store("export");
        std::fs::create_dir_all(&dir).unwrap();
        let mut db = WorkloadDb::new();
        let l = db.insert_new(char_of(2.0), vec![2.0, 4.0], 4, false);
        db.set_optimal_measured(l, ConfigIndex([1, 1, 1, 1, 1, 0]), 3.5);
        let path = dir.join("peer.kdb");
        KnowledgeStore::export(&db, &path, &BinaryCodec).unwrap();
        let back = KnowledgeStore::import(&path).unwrap();
        assert_eq!(durable_digest(&back), durable_digest(&db));
        // legacy bare WorkloadDb::save JSON imports through the same
        // entry point
        let legacy = dir.join("legacy.json");
        db.save(&legacy).unwrap();
        let old = KnowledgeStore::import(&legacy).unwrap();
        assert_eq!(durable_digest(&old), durable_digest(&db));
        std::fs::remove_dir_all(&dir).ok();
    }
}
