//! Crash-safe durable knowledge plane (ROADMAP item 1): the paper's
//! "long-term memory of workloads" made real across restarts.
//!
//! Layout on disk (one directory per knowledge store):
//!
//! ```text
//! store/
//!   snap-000001.kdb   oldest retained snapshot generation
//!   snap-000002.kdb   ...
//!   snap-000003.kdb   newest generation (loaded first at recovery)
//!   wal-000001.log    records appended after snap-000001 was written
//!   wal-000002.log    ...
//!   wal-000003.log    the active WAL (open for append)
//! ```
//!
//! * [`codec`] — pluggable [`SnapshotCodec`]: human-readable JSON for
//!   debugging, a compact self-describing binary for speed. Both
//!   encode the same deterministic `Json` tree, so the two formats are
//!   interchangeable byte-for-byte at the payload level.
//! * [`snapshot`] — the versioned envelope (magic, version, codec id,
//!   length, FNV-1a checksum) with atomic write-temp + fsync + rename,
//!   plus forward migration of old version headers and of legacy bare
//!   `WorkloadDb::save` JSON files.
//! * [`wal`] — the append-only log of insert / optimum / quarantine /
//!   drift / measurement records between snapshots; framed with
//!   per-record sequence numbers and checksums so a torn tail is
//!   detected, truncated, and survived.
//! * [`store`] — [`KnowledgeStore`]: generations + WAL + recovery
//!   ([`RecoveryReport`]), the seeded [`IoFaultPlan`] the chaos lab
//!   uses to prove the guarantees, and `export`/`import` so a fresh
//!   cluster seeds its DB from a peer's (federated knowledge).
//!
//! The recovery contract (pinned by `chaoslab::persistence` and
//! `tests/persistence.rs`): load the newest snapshot whose envelope
//! verifies, falling back a generation on checksum/parse failure;
//! replay every retained WAL record with a sequence number beyond the
//! snapshot's high-water mark; truncate (never trust) a torn WAL tail;
//! and never serve an entry from a snapshot that failed its checksum.

pub mod codec;
pub mod snapshot;
pub mod store;
pub mod wal;

pub use codec::{codec_for, BinaryCodec, JsonCodec, SnapshotCodec};
pub use snapshot::{read_snapshot, SNAPSHOT_VERSION};
pub use store::{
    durable_digest, IoFaultPlan, KnowledgeStore, PersistStats,
    RecoveryReport,
};
pub use wal::WalRecord;

/// FNV-1a 64-bit hash — the envelope and WAL-frame checksum. Not
/// cryptographic; it detects torn writes and bit flips, which is the
/// fault model here (a hostile disk is out of scope).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_is_stable_and_sensitive() {
        let a = fnv1a64(b"kermit");
        assert_eq!(a, fnv1a64(b"kermit"), "must be deterministic");
        assert_ne!(a, fnv1a64(b"kermis"), "one byte must change the hash");
        assert_ne!(a, fnv1a64(b"kermi"), "truncation must change the hash");
        // pinned known vector so the on-disk format never silently shifts
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
    }
}
