//! Knowledge-base zone layout (Figure 5): Landing Zone (raw agent data),
//! Transformation Zone (aggregated observation windows), Analytics Zone
//! (training sets, models, WorkloadDB).
//!
//! On the paper's cluster these are HDFS directories; here they are a
//! directory tree on the local filesystem with the same roles, written
//! as JSON-lines for the streaming zones.

use crate::features::{FeatureVec, ObservationWindow, NUM_FEATURES};
use crate::util::json::Json;
use std::io::{BufRead, Write};
use std::path::{Path, PathBuf};

/// Directory layout manager for the three zones.
#[derive(Debug, Clone)]
pub struct KnowledgeZones {
    pub root: PathBuf,
}

impl KnowledgeZones {
    /// Create (or open) the zone tree under `root`.
    pub fn create(root: &Path) -> std::io::Result<KnowledgeZones> {
        for sub in ["landing", "transformation", "analytics"] {
            std::fs::create_dir_all(root.join(sub))?;
        }
        Ok(KnowledgeZones { root: root.to_path_buf() })
    }

    pub fn landing(&self) -> PathBuf {
        self.root.join("landing")
    }

    pub fn transformation(&self) -> PathBuf {
        self.root.join("transformation")
    }

    pub fn analytics(&self) -> PathBuf {
        self.root.join("analytics")
    }

    pub fn workload_db_path(&self) -> PathBuf {
        self.analytics().join("workload_db.json")
    }

    /// Append raw agent samples to the landing zone (one JSONL file per
    /// agent, as §6.4: "There is one file for each agent").
    pub fn append_landing(
        &self,
        agent: &str,
        samples: &[(f64, FeatureVec)],
    ) -> std::io::Result<()> {
        let path = self.landing().join(format!("{agent}.jsonl"));
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)?;
        for (t, fv) in samples {
            let mut o = Json::obj();
            o.set("t", Json::Num(*t)).set("f", Json::from_f64_slice(fv));
            writeln!(f, "{}", o.encode())?;
        }
        Ok(())
    }

    /// Append aggregated observation windows to the transformation zone.
    pub fn append_windows(
        &self,
        windows: &[ObservationWindow],
    ) -> std::io::Result<()> {
        let path = self.transformation().join("windows.jsonl");
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)?;
        for w in windows {
            let mut o = Json::obj();
            o.set("index", Json::Num(w.index as f64))
                .set("time", Json::Num(w.time))
                .set("samples", Json::Num(w.samples as f64))
                .set("mean", Json::from_f64_slice(&w.mean))
                .set("var", Json::from_f64_slice(&w.var));
            writeln!(f, "{}", o.encode())?;
        }
        Ok(())
    }

    /// Stream observation windows back out of the transformation zone.
    pub fn read_windows(
        &self,
    ) -> crate::util::error::Result<Vec<ObservationWindow>> {
        let path = self.transformation().join("windows.jsonl");
        if !path.exists() {
            return Ok(vec![]);
        }
        let f = std::fs::File::open(path)?;
        let mut out = Vec::new();
        for line in std::io::BufReader::new(f).lines() {
            let line = line?;
            if line.trim().is_empty() {
                continue;
            }
            let j = Json::parse(&line)?;
            let mean_v = j.get("mean")?.f64s()?;
            let var_v = j.get("var")?.f64s()?;
            let mut mean = [0.0; NUM_FEATURES];
            let mut var = [0.0; NUM_FEATURES];
            mean.copy_from_slice(&mean_v[..NUM_FEATURES]);
            var.copy_from_slice(&var_v[..NUM_FEATURES]);
            out.push(ObservationWindow {
                index: j.get("index")?.as_usize()? as u64,
                time: j.get("time")?.as_f64()?,
                samples: j.get("samples")?.as_usize()?,
                mean,
                var,
                truth: None,
            });
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::features::zero_features;

    fn tmp(name: &str) -> PathBuf {
        let p = std::env::temp_dir().join(format!("kermit_zones_{name}"));
        std::fs::remove_dir_all(&p).ok();
        p
    }

    #[test]
    fn creates_zone_tree() {
        let root = tmp("tree");
        let z = KnowledgeZones::create(&root).unwrap();
        assert!(z.landing().is_dir());
        assert!(z.transformation().is_dir());
        assert!(z.analytics().is_dir());
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn windows_roundtrip() {
        let root = tmp("roundtrip");
        let z = KnowledgeZones::create(&root).unwrap();
        let mut f = zero_features();
        f[0] = 42.0;
        let w = ObservationWindow {
            index: 7,
            time: 123.5,
            samples: 30,
            mean: f,
            var: zero_features(),
            truth: Some(3),
        };
        z.append_windows(&[w.clone()]).unwrap();
        z.append_windows(&[ObservationWindow { index: 8, ..w.clone() }])
            .unwrap();
        let back = z.read_windows().unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back[0].index, 7);
        assert_eq!(back[0].mean[0], 42.0);
        assert_eq!(back[1].index, 8);
        // truth is generator-side only; it must NOT survive persistence
        assert_eq!(back[0].truth, None);
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn landing_appends_per_agent() {
        let root = tmp("landing");
        let z = KnowledgeZones::create(&root).unwrap();
        z.append_landing("agent0", &[(0.0, zero_features())]).unwrap();
        z.append_landing("agent1", &[(0.5, zero_features())]).unwrap();
        z.append_landing("agent0", &[(1.0, zero_features())]).unwrap();
        let a0 = std::fs::read_to_string(z.landing().join("agent0.jsonl")).unwrap();
        assert_eq!(a0.lines().count(), 2);
        assert!(z.landing().join("agent1.jsonl").exists());
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn read_windows_empty_when_missing() {
        let root = tmp("empty");
        let z = KnowledgeZones::create(&root).unwrap();
        assert!(z.read_windows().unwrap().is_empty());
        std::fs::remove_dir_all(&root).ok();
    }
}
