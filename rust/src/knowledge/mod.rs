//! The KERMIT workload knowledge base (paper §6.4, Figures 5 & 11):
//! WorkloadDB with workload characterizations, configurations and flags,
//! plus the landing/transformation/analytics zone layout.

pub mod workload_db;
pub mod zones;

pub use workload_db::{Characterization, WorkloadDb, WorkloadEntry};
pub use zones::KnowledgeZones;
