//! The KERMIT workload knowledge base (paper §6.4, Figures 5 & 11):
//! WorkloadDB with workload characterizations, configurations and flags,
//! plus the landing/transformation/analytics zone layout.

pub mod persist;
pub mod workload_db;
pub mod zones;

pub use persist::{
    BinaryCodec, IoFaultPlan, JsonCodec, KnowledgeStore, RecoveryReport,
    SnapshotCodec,
};
pub use workload_db::{Characterization, WorkloadDb, WorkloadEntry};
pub use zones::KnowledgeZones;

/// The shared knowledge plane: one WorkloadDB behind a read/write lock,
/// handed to every consumer (N pipeline shards, N plug-in instances,
/// the off-line analyser). Reads — classification gates, Algorithm 1
/// cache lookups — vastly outnumber writes (discovery inserts, config
/// updates), so an `RwLock` lets all tenants read concurrently while a
/// class discovered from tenant A's traffic becomes visible to tenant B
/// the moment the write lock drops (the paper's cross-workload
/// learning: one long-term memory, many streams).
pub type SharedWorkloadDb =
    std::sync::Arc<std::sync::RwLock<WorkloadDb>>;

/// Fresh empty shared knowledge plane.
pub fn shared_db() -> SharedWorkloadDb {
    std::sync::Arc::new(std::sync::RwLock::new(WorkloadDb::new()))
}
