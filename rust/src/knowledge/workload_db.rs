//! WorkloadDB — the entity model of Figure 11.
//!
//! Each workload is keyed by its generated integer label (paper §7.1:
//! "KERMIT implements a simple integer counter") and stores:
//! * the workload characterization — per-feature statistics (mean, std,
//!   min, max, p75, p90) over the member observation windows;
//! * the cluster centroid;
//! * `optimal_config_found` flag and the stored configuration;
//! * `is_drifting` flag.
//!
//! Workloads are never deleted ("KERMIT retains a long-term memory of
//! workloads"). Persistence is JSON through `util::json` so the DB
//! survives restarts and is human-inspectable.

use crate::features::NUM_FEATURES;
use crate::knowledge::persist::wal::WalRecord;
use crate::linalg::Matrix;
use crate::simcluster::config_space::ConfigIndex;
use crate::stats::Summary;
use crate::util::json::{Json, JsonError};
use std::collections::BTreeMap;

/// Per-feature statistics of a workload's observation windows — the
/// paper's "workload characterization" (§7.1).
#[derive(Debug, Clone, PartialEq)]
pub struct Characterization {
    /// One Summary per feature (NUM_FEATURES wide; analytic windows use
    /// 2x width — the width is carried by the data).
    pub per_feature: Vec<Summary>,
}

impl Characterization {
    /// Characterize a cluster of feature vectors (contiguous rows).
    pub fn from_rows(rows: &Matrix) -> Characterization {
        assert!(!rows.is_empty());
        let w = rows.n_cols();
        let mut col: Vec<f64> = Vec::with_capacity(rows.n_rows());
        let per_feature = (0..w)
            .map(|j| {
                col.clear();
                col.extend(rows.iter_rows().map(|r| r[j]));
                Summary::of(&col)
            })
            .collect();
        Characterization { per_feature }
    }

    /// Boundary shim: characterize `Vec<Vec<f64>>` rows by converting
    /// once into contiguous storage.
    pub fn from_vec_rows(rows: &[Vec<f64>]) -> Characterization {
        Characterization::from_rows(&Matrix::from_rows(rows))
    }

    pub fn mean_vector(&self) -> Vec<f64> {
        self.per_feature.iter().map(|s| s.mean).collect()
    }

    /// L2 distance between mean vectors — the drift / identity metric of
    /// Algorithm 2. Computed directly over the summaries (no temporary
    /// vectors: this runs once per DB entry on every `nearest` lookup).
    pub fn mean_distance(&self, other: &Characterization) -> f64 {
        assert_eq!(self.per_feature.len(), other.per_feature.len());
        self.per_feature
            .iter()
            .zip(&other.per_feature)
            .map(|(a, b)| (a.mean - b.mean) * (a.mean - b.mean))
            .sum::<f64>()
            .sqrt()
    }
}

/// One WorkloadDB row (Figure 11).
#[derive(Debug, Clone)]
pub struct WorkloadEntry {
    pub label: u32,
    pub characterization: Characterization,
    pub centroid: Vec<f64>,
    pub optimal_config_found: bool,
    pub is_drifting: bool,
    /// Stored configuration (may be non-optimal when drifting).
    pub config: Option<ConfigIndex>,
    /// Number of observation windows characterised (bookkeeping).
    pub window_count: usize,
    /// True for ZSL-synthesised anticipated classes (paper §7.2 7c).
    pub synthetic: bool,
    /// For synthetic classes: the (pure, pure) parent pair.
    pub parents: Option<(u32, u32)>,
    /// Poisoned/corrupt entry: its stored optimum must never be served
    /// or used to seed a search until a fresh search re-earns trust.
    /// The entry itself stays (labels are never deleted) so the same
    /// workload re-heals in place instead of forking a new label.
    pub quarantined: bool,
    /// Measured duration of the stored optimum (when it came from a
    /// finished search) — the baseline the poisoning detector compares
    /// live cache-hit runs against.
    pub best_duration: Option<f64>,
}

/// The database: label -> entry, with a monotone label counter.
#[derive(Debug, Default)]
pub struct WorkloadDb {
    entries: BTreeMap<u32, WorkloadEntry>,
    next_label: u32,
    /// Durable-plane journal: mutations since the last `take_journal`.
    /// Empty (and never grows) unless journaling is enabled, so a DB
    /// without an attached store pays nothing.
    journal: Vec<WalRecord>,
    journaling: bool,
}

impl WorkloadDb {
    pub fn new() -> WorkloadDb {
        WorkloadDb::default()
    }

    /// Start journaling mutations (a durable store is attached). WAL
    /// replay during recovery runs *before* this, so replayed records
    /// are never re-journaled.
    pub fn enable_journal(&mut self) {
        self.journaling = true;
    }

    /// Drain the journaled mutations; the caller appends them to the
    /// WAL. Always empty when journaling is off.
    pub fn take_journal(&mut self) -> Vec<WalRecord> {
        std::mem::take(&mut self.journal)
    }

    fn record(&mut self, r: WalRecord) {
        if self.journaling {
            self.journal.push(r);
        }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn get(&self, label: u32) -> Option<&WorkloadEntry> {
        self.entries.get(&label)
    }

    pub fn get_mut(&mut self, label: u32) -> Option<&mut WorkloadEntry> {
        self.entries.get_mut(&label)
    }

    pub fn labels(&self) -> Vec<u32> {
        self.entries.keys().copied().collect()
    }

    pub fn entries(&self) -> impl Iterator<Item = &WorkloadEntry> {
        self.entries.values()
    }

    /// Insert a newly discovered workload; assigns and returns the next
    /// integer label (paper §7.1 label generation).
    pub fn insert_new(
        &mut self,
        characterization: Characterization,
        centroid: Vec<f64>,
        window_count: usize,
        synthetic: bool,
    ) -> u32 {
        self.insert_with_parents(
            characterization,
            centroid,
            window_count,
            synthetic,
            None,
        )
    }

    /// Insert with an explicit parent pair (ZSL-synthesised classes).
    pub fn insert_with_parents(
        &mut self,
        characterization: Characterization,
        centroid: Vec<f64>,
        window_count: usize,
        synthetic: bool,
        parents: Option<(u32, u32)>,
    ) -> u32 {
        let label = self.next_label;
        self.next_label += 1;
        let entry = WorkloadEntry {
            label,
            characterization,
            centroid,
            optimal_config_found: false,
            is_drifting: false,
            config: None,
            window_count,
            synthetic,
            parents,
            quarantined: false,
            best_duration: None,
        };
        self.record(WalRecord::Insert(Box::new(entry.clone())));
        self.entries.insert(label, entry);
        label
    }

    /// Reinstall an entry verbatim during WAL replay (recovery path).
    /// Keeps the label counter monotone past every restored label; does
    /// not journal — a replayed record is already durable.
    pub fn restore_entry(&mut self, e: WorkloadEntry) {
        self.next_label = self.next_label.max(e.label + 1);
        self.entries.insert(e.label, e);
    }

    /// True if a synthetic class for this (unordered) parent pair exists.
    pub fn has_synthetic_pair(&self, a: u32, b: u32) -> bool {
        let key = if a < b { (a, b) } else { (b, a) };
        self.entries
            .values()
            .any(|e| e.synthetic && e.parents == Some(key))
    }

    /// Find the stored workload whose characterization mean is nearest
    /// to `c`, returning (label, distance). Used by Algorithm 2's "find
    /// match in WorkloadDB" (via the ChangeDetector statistic) and by the
    /// on-line classifier's nearest-centroid fallback.
    pub fn nearest(&self, c: &Characterization) -> Option<(u32, f64)> {
        // a corrupt (NaN) stored characterization must neither win the
        // match nor panic the partial_cmp — skip non-finite distances
        self.entries
            .values()
            .map(|e| (e.label, e.characterization.mean_distance(c)))
            .filter(|(_, d)| d.is_finite())
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
    }

    /// Nearest among *observed* (non-synthetic) workloads — what
    /// Algorithm 2's match step uses: a discovered cluster is real data
    /// and must not merge into a ZSL prototype. (A hybrid that matches
    /// its anticipated prototype still gets its own observed entry; the
    /// classifier handles naming hybrids, the DB tracks observations.)
    pub fn nearest_observed(&self, c: &Characterization) -> Option<(u32, f64)> {
        self.entries
            .values()
            .filter(|e| !e.synthetic)
            .map(|e| (e.label, e.characterization.mean_distance(c)))
            .filter(|(_, d)| d.is_finite())
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
    }

    /// Record the optimal configuration for a workload (Algorithm 1's
    /// "Update WorkloadDB with J_i^o"). A completed search also lifts
    /// any quarantine: the optimum was just re-earned.
    pub fn set_optimal_config(&mut self, label: u32, config: ConfigIndex) {
        self.apply_optimal(label, config, None);
    }

    /// Like [`set_optimal_config`](Self::set_optimal_config) but also
    /// records the measured duration of the optimum, arming the
    /// cache-poisoning detector for this label.
    pub fn set_optimal_measured(
        &mut self,
        label: u32,
        config: ConfigIndex,
        duration: f64,
    ) {
        self.apply_optimal(
            label,
            config,
            duration.is_finite().then_some(duration),
        );
    }

    /// Shared body of the two optimum setters: one mutation, one
    /// journal record (never two for a measured optimum).
    fn apply_optimal(
        &mut self,
        label: u32,
        config: ConfigIndex,
        duration: Option<f64>,
    ) {
        let e = self.entries.get_mut(&label).expect("unknown label");
        e.config = Some(config);
        e.optimal_config_found = true;
        e.is_drifting = false;
        e.quarantined = false;
        e.best_duration = duration;
        self.record(WalRecord::Optimum { label, config, duration });
    }

    /// Quarantine a poisoned entry: its stored optimum is untrusted and
    /// must not be served, but the config is kept for forensics. Returns
    /// false for unknown labels (quarantining is best-effort).
    pub fn quarantine(&mut self, label: u32) -> bool {
        match self.entries.get_mut(&label) {
            Some(e) => {
                e.quarantined = true;
                // every "serve the stored optimum" path filters on this
                // flag, so clearing it contains the poison immediately
                e.optimal_config_found = false;
                e.best_duration = None;
                self.record(WalRecord::Quarantine { label });
                true
            }
            None => false,
        }
    }

    /// Labels currently under quarantine.
    pub fn quarantined_labels(&self) -> Vec<u32> {
        self.entries
            .values()
            .filter(|e| e.quarantined)
            .map(|e| e.label)
            .collect()
    }

    /// Integrity sweep: quarantine entries whose stored state is
    /// structurally corrupt — non-finite centroid or characterization
    /// statistics, or a stored config outside the tuning grid. Returns
    /// the labels quarantined by *this* sweep. Run by the coordinator's
    /// off-line phase so a corrupt write is contained within one cycle.
    pub fn audit_quarantine(&mut self) -> Vec<u32> {
        let bad: Vec<u32> = self
            .entries
            .values()
            .filter(|e| !e.quarantined)
            .filter(|e| {
                let centroid_bad =
                    e.centroid.iter().any(|v| !v.is_finite());
                let char_bad = e
                    .characterization
                    .per_feature
                    .iter()
                    .any(|s| !s.mean.is_finite() || !s.std.is_finite());
                let config_bad =
                    e.config.map(|c| c.clamped() != c).unwrap_or(false);
                centroid_bad || char_bad || config_bad
            })
            .map(|e| e.label)
            .collect();
        for &l in &bad {
            self.quarantine(l);
        }
        bad
    }

    /// Mark drift: keeps the stale config but clears the optimal flag
    /// (Algorithm 2's "update isDrifting to True").
    pub fn mark_drifting(
        &mut self,
        label: u32,
        new_characterization: Characterization,
        new_centroid: Vec<f64>,
        window_count: usize,
    ) {
        let e = self.entries.get_mut(&label).expect("unknown label");
        e.is_drifting = true;
        e.optimal_config_found = false;
        e.characterization = new_characterization;
        e.centroid = new_centroid;
        e.window_count = window_count;
        // only the trust flags are journaled; the refreshed
        // characterization is derivable from live traffic after a
        // restart and a stale one only inflates one match distance
        self.record(WalRecord::Drift { label });
    }

    /// Refresh a matched (non-drifting) workload's characterization with
    /// new data (Algorithm 2's regular update).
    pub fn refresh(
        &mut self,
        label: u32,
        characterization: Characterization,
        window_count: usize,
    ) {
        let e = self.entries.get_mut(&label).expect("unknown label");
        e.characterization = characterization;
        e.window_count += window_count;
    }

    // ---- persistence -----------------------------------------------------

    pub fn to_json(&self) -> Json {
        let workloads =
            self.entries.values().map(entry_to_json).collect();
        let mut root = Json::obj();
        root.set("next_label", Json::Num(self.next_label as f64))
            .set("workloads", Json::Arr(workloads));
        root
    }

    pub fn from_json(j: &Json) -> Result<WorkloadDb, JsonError> {
        let mut db = WorkloadDb::new();
        db.next_label = j.get("next_label")?.as_usize()? as u32;
        for w in j.get("workloads")?.as_arr()? {
            let e = entry_from_json(w)?;
            db.entries.insert(e.label, e);
        }
        Ok(db)
    }

    pub fn save(&self, path: &std::path::Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().encode_pretty())
    }

    pub fn load(path: &std::path::Path) -> crate::util::error::Result<WorkloadDb> {
        let text = std::fs::read_to_string(path)?;
        Ok(WorkloadDb::from_json(&Json::parse(&text)?)?)
    }
}

/// Serialize one entry — the shared schema for `WorkloadDb::to_json`
/// workload rows and WAL `insert` records (one schema, one migration
/// story for both).
pub fn entry_to_json(e: &WorkloadEntry) -> Json {
    let mut o = Json::obj();
    o.set("label", Json::Num(e.label as f64))
        .set("optimal_config_found", Json::Bool(e.optimal_config_found))
        .set("is_drifting", Json::Bool(e.is_drifting))
        .set("window_count", Json::Num(e.window_count as f64))
        .set("synthetic", Json::Bool(e.synthetic))
        .set("quarantined", Json::Bool(e.quarantined))
        .set(
            "best_duration",
            match e.best_duration {
                Some(d) => Json::Num(d),
                None => Json::Null,
            },
        )
        .set("centroid", Json::from_f64_slice(&e.centroid))
        .set(
            "characterization",
            Json::Arr(
                e.characterization
                    .per_feature
                    .iter()
                    .map(|s| {
                        Json::from_f64_slice(&[
                            s.n as f64, s.mean, s.std, s.min, s.max,
                            s.p75, s.p90,
                        ])
                    })
                    .collect(),
            ),
        );
    match e.config {
        Some(ci) => {
            o.set(
                "config",
                Json::Arr(
                    ci.0.iter().map(|&i| Json::Num(i as f64)).collect(),
                ),
            );
        }
        None => {
            o.set("config", Json::Null);
        }
    }
    match e.parents {
        Some((a, b)) => {
            o.set("parents", Json::from_f64_slice(&[a as f64, b as f64]));
        }
        None => {
            o.set("parents", Json::Null);
        }
    }
    o
}

/// Parse one entry. Tolerates pre-quarantine-era rows (no
/// `quarantined` / `best_duration` keys — default to trusted) so every
/// snapshot generation ever written still loads.
pub fn entry_from_json(w: &Json) -> Result<WorkloadEntry, JsonError> {
    let label = w.get("label")?.as_usize()? as u32;
    let per_feature = w
        .get("characterization")?
        .as_arr()?
        .iter()
        .map(|s| {
            let v = s.f64s()?;
            Ok(Summary {
                n: v[0] as usize,
                mean: v[1],
                std: v[2],
                min: v[3],
                max: v[4],
                p75: v[5],
                p90: v[6],
            })
        })
        .collect::<Result<Vec<_>, JsonError>>()?;
    let config = match w.get("config")? {
        Json::Null => None,
        arr => {
            let v = arr.f64s()?;
            let mut idx = [0usize; 6];
            for (d, x) in v.iter().enumerate().take(6) {
                idx[d] = *x as usize;
            }
            Some(ConfigIndex(idx))
        }
    };
    let parents = match w.get_opt("parents") {
        None | Some(Json::Null) => None,
        Some(arr) => {
            let v = arr.f64s()?;
            Some((v[0] as u32, v[1] as u32))
        }
    };
    // both absent in pre-chaos-lab snapshots: default to trusted
    let quarantined = match w.get_opt("quarantined") {
        None | Some(Json::Null) => false,
        Some(b) => b.as_bool()?,
    };
    let best_duration = match w.get_opt("best_duration") {
        None | Some(Json::Null) => None,
        Some(n) => Some(n.as_f64()?),
    };
    Ok(WorkloadEntry {
        label,
        characterization: Characterization { per_feature },
        centroid: w.get("centroid")?.f64s()?,
        optimal_config_found: w.get("optimal_config_found")?.as_bool()?,
        is_drifting: w.get("is_drifting")?.as_bool()?,
        config,
        window_count: w.get("window_count")?.as_usize()?,
        synthetic: w.get("synthetic")?.as_bool()?,
        parents,
        quarantined,
        best_duration,
    })
}

/// Helper: characterization width for raw observation windows.
pub fn obs_window_width() -> usize {
    NUM_FEATURES
}

#[cfg(test)]
mod tests {
    use super::*;

    fn char_of(mean: f64, n: usize) -> Characterization {
        let rows: Vec<Vec<f64>> =
            (0..n).map(|i| vec![mean + (i % 2) as f64, 2.0 * mean]).collect();
        Characterization::from_vec_rows(&rows)
    }

    #[test]
    fn labels_are_monotone_and_never_reused() {
        let mut db = WorkloadDb::new();
        let a = db.insert_new(char_of(1.0, 4), vec![1.0, 2.0], 4, false);
        let b = db.insert_new(char_of(9.0, 4), vec![9.0, 18.0], 4, false);
        assert_eq!((a, b), (0, 1));
        // no delete API exists; labels only grow
        let c = db.insert_new(char_of(5.0, 4), vec![5.0, 10.0], 4, true);
        assert_eq!(c, 2);
        assert_eq!(db.len(), 3);
    }

    #[test]
    fn nearest_finds_closest_mean() {
        let mut db = WorkloadDb::new();
        db.insert_new(char_of(0.0, 4), vec![0.0, 0.0], 4, false);
        db.insert_new(char_of(10.0, 4), vec![10.0, 20.0], 4, false);
        let (label, d) = db.nearest(&char_of(9.0, 4)).unwrap();
        assert_eq!(label, 1);
        assert!(d < 3.0);
    }

    #[test]
    fn config_lifecycle() {
        let mut db = WorkloadDb::new();
        let l = db.insert_new(char_of(1.0, 4), vec![1.0, 2.0], 4, false);
        assert!(!db.get(l).unwrap().optimal_config_found);
        db.set_optimal_config(l, ConfigIndex([1, 2, 3, 4, 5, 0]));
        let e = db.get(l).unwrap();
        assert!(e.optimal_config_found);
        assert_eq!(e.config, Some(ConfigIndex([1, 2, 3, 4, 5, 0])));
        // drift clears the flag but keeps the config for local search
        db.mark_drifting(l, char_of(2.0, 4), vec![2.0, 4.0], 4);
        let e = db.get(l).unwrap();
        assert!(e.is_drifting && !e.optimal_config_found);
        assert!(e.config.is_some());
    }

    #[test]
    fn json_roundtrip() {
        let mut db = WorkloadDb::new();
        let l0 = db.insert_new(char_of(1.5, 6), vec![1.5, 3.0], 6, false);
        db.insert_new(char_of(7.0, 3), vec![7.0, 14.0], 3, true);
        db.set_optimal_config(l0, ConfigIndex([0, 1, 2, 3, 4, 1]));
        let j = db.to_json();
        let back = WorkloadDb::from_json(&j).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back.next_label, db.next_label);
        let e = back.get(l0).unwrap();
        assert!(e.optimal_config_found);
        assert_eq!(e.config, Some(ConfigIndex([0, 1, 2, 3, 4, 1])));
        assert_eq!(
            e.characterization.per_feature[0].mean,
            db.get(l0).unwrap().characterization.per_feature[0].mean
        );
        let s = back.get(1).unwrap();
        assert!(s.synthetic);
        assert_eq!(s.config, None);
    }

    #[test]
    fn save_load_file() {
        let dir = std::env::temp_dir().join("kermit_db_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("db.json");
        let mut db = WorkloadDb::new();
        db.insert_new(char_of(3.0, 5), vec![3.0, 6.0], 5, false);
        db.save(&path).unwrap();
        let back = WorkloadDb::load(&path).unwrap();
        assert_eq!(back.len(), 1);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn quarantine_lifecycle_contains_and_heals() {
        let mut db = WorkloadDb::new();
        let l = db.insert_new(char_of(1.0, 4), vec![1.0, 2.0], 4, false);
        db.set_optimal_measured(l, ConfigIndex([1, 2, 3, 4, 5, 0]), 42.0);
        let e = db.get(l).unwrap();
        assert!(e.optimal_config_found);
        assert_eq!(e.best_duration, Some(42.0));

        assert!(db.quarantine(l));
        let e = db.get(l).unwrap();
        assert!(e.quarantined);
        assert!(!e.optimal_config_found, "quarantine must clear trust");
        assert!(e.config.is_some(), "config kept for forensics");
        assert_eq!(db.quarantined_labels(), vec![l]);
        assert!(!db.quarantine(999), "unknown label is best-effort");

        // a fresh search re-earns trust and lifts the quarantine
        db.set_optimal_measured(l, ConfigIndex([2, 2, 2, 2, 2, 0]), 30.0);
        let e = db.get(l).unwrap();
        assert!(!e.quarantined && e.optimal_config_found);
        assert!(db.quarantined_labels().is_empty());
    }

    #[test]
    fn nearest_skips_nan_characterizations() {
        let mut db = WorkloadDb::new();
        let good = db.insert_new(char_of(1.0, 4), vec![1.0, 2.0], 4, false);
        let bad = db.insert_new(char_of(9.0, 4), vec![9.0, 18.0], 4, false);
        for s in &mut db.get_mut(bad).unwrap().characterization.per_feature
        {
            s.mean = f64::NAN;
        }
        // nearest must neither panic nor match the corrupt entry, even
        // when the query sits right on top of it
        let (l, d) = db.nearest(&char_of(9.0, 4)).unwrap();
        assert_eq!(l, good);
        assert!(d.is_finite());
        let (l2, _) = db.nearest_observed(&char_of(9.0, 4)).unwrap();
        assert_eq!(l2, good);
    }

    #[test]
    fn audit_quarantines_corrupt_entries_once() {
        let mut db = WorkloadDb::new();
        let ok = db.insert_new(char_of(1.0, 4), vec![1.0, 2.0], 4, false);
        let nan_centroid =
            db.insert_new(char_of(2.0, 4), vec![f64::NAN, 4.0], 4, false);
        let off_grid = db.insert_new(char_of(3.0, 4), vec![3.0, 6.0], 4, false);
        db.get_mut(off_grid).unwrap().config =
            Some(ConfigIndex([99, 0, 0, 0, 0, 0]));

        let mut swept = db.audit_quarantine();
        swept.sort_unstable();
        assert_eq!(swept, vec![nan_centroid, off_grid]);
        assert!(!db.get(ok).unwrap().quarantined);
        // idempotent: already-quarantined entries are not re-reported
        assert!(db.audit_quarantine().is_empty());
    }

    #[test]
    fn json_roundtrip_keeps_quarantine_and_is_backward_compatible() {
        let mut db = WorkloadDb::new();
        let l0 = db.insert_new(char_of(1.0, 4), vec![1.0, 2.0], 4, false);
        let l1 = db.insert_new(char_of(5.0, 4), vec![5.0, 10.0], 4, false);
        db.set_optimal_measured(l0, ConfigIndex([1, 1, 1, 1, 1, 0]), 17.5);
        db.quarantine(l1);
        let back = WorkloadDb::from_json(&db.to_json()).unwrap();
        assert_eq!(back.get(l0).unwrap().best_duration, Some(17.5));
        assert!(back.get(l1).unwrap().quarantined);

        // a snapshot written before the chaos lab lacks both keys
        let mut j = db.to_json();
        let pruned: Vec<Json> = j
            .get("workloads")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|w| {
                let mut o = Json::obj();
                for k in [
                    "label",
                    "optimal_config_found",
                    "is_drifting",
                    "window_count",
                    "synthetic",
                    "centroid",
                    "characterization",
                    "config",
                    "parents",
                ] {
                    o.set(k, w.get(k).unwrap().clone());
                }
                o
            })
            .collect();
        j.set("workloads", Json::Arr(pruned));
        let old = WorkloadDb::from_json(&j).unwrap();
        assert!(!old.get(l0).unwrap().quarantined);
        assert_eq!(old.get(l0).unwrap().best_duration, None);
    }

    #[test]
    fn journal_captures_each_mutation_exactly_once() {
        let mut db = WorkloadDb::new();
        // journaling off: nothing accumulates
        let l0 = db.insert_new(char_of(1.0, 4), vec![1.0, 2.0], 4, false);
        assert!(db.take_journal().is_empty());

        db.enable_journal();
        let l1 = db.insert_new(char_of(5.0, 4), vec![5.0, 10.0], 4, false);
        db.set_optimal_measured(l1, ConfigIndex([1, 1, 1, 1, 1, 0]), 20.0);
        db.set_optimal_config(l0, ConfigIndex([2, 2, 2, 2, 2, 0]));
        db.quarantine(l0);
        db.quarantine(999); // unknown: no record
        db.mark_drifting(l1, char_of(6.0, 4), vec![6.0, 12.0], 4);
        db.refresh(l1, char_of(6.5, 4), 2); // refresh is NOT journaled

        let j = db.take_journal();
        assert_eq!(j.len(), 5);
        assert!(matches!(&j[0], WalRecord::Insert(e) if e.label == l1));
        // a measured optimum journals ONE record carrying the duration
        assert!(matches!(
            j[1],
            WalRecord::Optimum { label, duration: Some(d), .. }
                if label == l1 && d == 20.0
        ));
        assert!(matches!(
            j[2],
            WalRecord::Optimum { label, duration: None, .. }
                if label == l0
        ));
        assert!(matches!(j[3], WalRecord::Quarantine { label } if label == l0));
        assert!(matches!(j[4], WalRecord::Drift { label } if label == l1));
        // drained: a second take is empty
        assert!(db.take_journal().is_empty());
    }

    #[test]
    fn restore_entry_keeps_labels_monotone() {
        let mut db = WorkloadDb::new();
        let mut src = WorkloadDb::new();
        let l = src.insert_new(char_of(3.0, 4), vec![3.0, 6.0], 4, false);
        src.set_optimal_measured(l, ConfigIndex([0, 1, 0, 1, 0, 1]), 9.5);
        let e = src.get(l).unwrap().clone();
        db.restore_entry(e);
        assert_eq!(db.get(l).unwrap().best_duration, Some(9.5));
        // the counter moved past the restored label: no reuse
        let next = db.insert_new(char_of(8.0, 4), vec![8.0, 16.0], 4, false);
        assert_eq!(next, l + 1);
    }

    #[test]
    fn mean_distance_is_metric_like() {
        let a = char_of(0.0, 4);
        let b = char_of(3.0, 4);
        assert_eq!(a.mean_distance(&a), 0.0);
        assert!((a.mean_distance(&b) - b.mean_distance(&a)).abs() < 1e-12);
        assert!(a.mean_distance(&b) > 0.0);
    }
}
