//! Zero-shot hybrid-workload classification (paper/[9]: up to 83% on
//! unseen multi-user workloads), with the no-synthesizer ablation.

use kermit::benchkit::{pct, Table};
use kermit::experiments::zsl;

fn main() {
    println!("\n== ZSL: anticipating unseen hybrid workloads ==");
    println!("paper [9]: classify unseen hybrids with up to 83% accuracy\n");
    let mut t = Table::new(&[
        "seed", "hybrid_tests", "zsl_accuracy", "ablation(no synth)",
        "pure_accuracy",
    ]);
    let mut best = 0.0f64;
    for seed in [3u64, 7, 13] {
        let r = zsl::run(seed);
        best = best.max(r.zsl_accuracy);
        t.row(&[
            seed.to_string(),
            r.n_hybrid_tests.to_string(),
            pct(r.zsl_accuracy),
            pct(r.ablation_accuracy),
            pct(r.pure_accuracy),
        ]);
    }
    t.print();
    println!("\nbest zsl accuracy: {} (paper: up to 83%)", pct(best));
}
