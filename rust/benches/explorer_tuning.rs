//! Headline tuning claims: Explorer ≥30% faster than rule-of-thumb,
//! ≥92% of exhaustive-best ("tuning efficiency"), at <1% of the probe
//! cost — per workload class, under measurement noise.

use kermit::benchkit::{bench, pct, Table};
use kermit::experiments::explorer_table::{run, summarize};
use kermit::explorer::Explorer;
use kermit::simcluster::config_space::ConfigIndex;
use kermit::simcluster::perfmodel::job_duration;

fn main() {
    println!("\n== Explorer tuning efficiency (paper §1/§6.4) ==");
    println!("paper: 30% faster than rule-of-thumb, up to 92.5% of best\n");
    let rows = run(0, 0.03);
    let mut t = Table::new(&[
        "class", "default(s)", "rule-of-thumb(s)", "random(s)",
        "explorer(s)", "oracle(s)", "probes", "efficiency", "vs RoT",
    ]);
    for r in &rows {
        t.row(&[
            r.class.to_string(),
            format!("{:.1}", r.default_s),
            format!("{:.1}", r.rot_s),
            format!("{:.1}", r.random_s),
            format!("{:.1}", r.explorer_s),
            format!("{:.1}", r.oracle_s),
            r.explorer_probes.to_string(),
            pct(r.efficiency),
            pct(r.vs_rot),
        ]);
    }
    t.print();
    let s = summarize(&rows);
    println!(
        "\nmean efficiency {} (max {}) | mean vs rule-of-thumb {} (max {}) | mean probes {:.0} of {} grid points",
        pct(s.mean_efficiency),
        pct(s.max_efficiency),
        pct(s.mean_vs_rot),
        pct(s.max_vs_rot),
        s.mean_probes,
        ConfigIndex::grid_size(),
    );

    // --- ablation: probe budget vs tuning efficiency (noise-free) ----
    println!("\n-- budget ablation (mean/min efficiency across classes) --");
    let oracle: Vec<f64> = (0..10u32)
        .map(|c| {
            let mut e = |ci: ConfigIndex| job_duration(c, &ci.to_config());
            kermit::explorer::baselines::exhaustive(&mut e).best_duration
        })
        .collect();
    let mut ta = Table::new(&["budget", "mean_eff", "min_eff"]);
    for budget in [12usize, 16, 20, 25, 30, 40, 60, 90, 140] {
        let mut effs = Vec::new();
        for c in 0..10u32 {
            let mut e = |ci: ConfigIndex| job_duration(c, &ci.to_config());
            let ex = Explorer::new(kermit::explorer::ExplorerConfig {
                global_budget: budget,
                local_budget: 16,
                min_improvement: 0.002,
            });
            let r = ex.global_search(&mut e);
            effs.push(oracle[c as usize] / r.best_duration);
        }
        let mean = effs.iter().sum::<f64>() / effs.len() as f64;
        let min = effs.iter().copied().fold(f64::INFINITY, f64::min);
        ta.row(&[budget.to_string(), pct(mean), pct(min)]);
    }
    ta.print();

    // search wall-clock (the coordinator-side overhead, excl. job runs)
    let timing = bench(1, 5, || {
        let ex = Explorer::with_defaults();
        let mut eval = |c: ConfigIndex| job_duration(2, &c.to_config());
        std::hint::black_box(ex.global_search(&mut eval));
    });
    println!("\nexplorer search wall-clock: {}", timing.per_iter_str());
}
