//! Transport-chaos runner: the ingest path under a faulty link
//! (partition + heal, lossy/laggy transport, duplicate storm, stalled
//! consumer), each scenario scored against its fault-free oracle for
//! the supervision guarantees (bounded regret, exactly-once window
//! accounting, injected ≥ observed counter reconciliation, zero
//! permanently-degraded tenants). Writes the deterministic
//! per-scenario JSON snapshots to `TRANSPORT_outcomes.json` (the CI
//! artifact — a failure reproduces locally from its seed via
//! `KERMIT_CHAOS_SEED`).
//!
//! With `KERMIT_SMOKE=1` the sweep shrinks to toy sizes and *asserts*
//! every scenario passes — the blocking `rust-transport-chaos` CI job.

use kermit::benchkit::Table;
use kermit::experiments::chaos;
use kermit::util::json::Json;

fn main() {
    let smoke = matches!(
        std::env::var("KERMIT_SMOKE").as_deref(),
        Ok(v) if !v.is_empty() && v != "0"
    );

    println!(
        "\n== Transport chaos (faulty ingest link vs fault-free oracle) ==\n"
    );
    let t0 = std::time::Instant::now();
    let outcomes = chaos::run_transport(smoke);
    let wall = t0.elapsed();

    let mut t = Table::new(&[
        "scenario",
        "regret",
        "bound",
        "sent",
        "dropped",
        "dup/dedup",
        "gaps",
        "dbl-count",
        "degraded",
        "tail hit (o/f)",
        "verdict",
    ]);
    for o in &outcomes {
        t.row(&[
            o.name.clone(),
            format!("{:+.3}", o.regret),
            format!("{:.2}", o.regret_bound),
            format!("{}", o.samples_sent),
            format!("{}", o.samples_dropped + o.samples_partitioned),
            format!("{}/{}", o.samples_duplicated, o.deduped),
            format!("{}", o.gaps_skipped),
            format!("{}", o.double_counted_windows),
            format!("{}/{}", o.degraded_events, o.degraded_final),
            format!(
                "{:.0}%/{:.0}%",
                100.0 * o.oracle_tail_hit_ratio,
                100.0 * o.faulted_tail_hit_ratio
            ),
            if o.pass { "pass".into() } else { "FAIL".into() },
        ]);
        for f in &o.failures {
            println!("{}: FAIL — {f}", o.name);
        }
    }
    t.print();
    println!(
        "\n{} scenarios, wall {:.1}s",
        outcomes.len(),
        wall.as_secs_f64()
    );

    // deterministic JSON snapshots: same seeds → same bytes
    let snapshot =
        Json::Arr(outcomes.iter().map(|o| o.to_json()).collect());
    let path = "TRANSPORT_outcomes.json";
    match std::fs::write(path, snapshot.encode_pretty()) {
        Ok(()) => println!("snapshots written to {path}"),
        Err(e) => println!("snapshot write failed ({path}): {e}"),
    }

    if smoke {
        for o in &outcomes {
            assert!(
                o.pass,
                "scenario {} violated its transport guarantees: {:?}",
                o.name, o.failures
            );
        }
        println!("\ntransport chaos smoke OK");
    }
}
