//! End-to-end autonomic loop: KERMIT vs default config vs rule-of-thumb
//! vs oracle, on a recurring multi-workload "day" — the integration of
//! every sub-system (discovery, classification, prediction, Algorithm 1,
//! Explorer sessions, drift handling).

use kermit::benchkit::{pct, Table};
use kermit::coordinator::{
    run_fixed_config, run_oracle, Coordinator, CoordinatorConfig,
};
use kermit::explorer::baselines::rule_of_thumb;
use kermit::simcluster::{default_config_index, JobSpec};
use kermit::workloadgen::Mix;

fn main() {
    println!("\n== End-to-end autonomic loop (recurring day) ==\n");
    let classes = [0u32, 3, 5];
    let cycles = 60;
    let mut jobs = Vec::new();
    for _ in 0..cycles {
        for &c in &classes {
            jobs.push(JobSpec { mix: Mix::Pure(c) });
        }
    }
    println!(
        "schedule: {} jobs ({} classes x {} cycles)",
        jobs.len(),
        classes.len(),
        cycles
    );

    let mut cfg = CoordinatorConfig::default();
    cfg.offline_interval_windows = 12;
    cfg.engine.duration_noise = 0.02;
    let mut coord = Coordinator::new(cfg.clone());
    // the on-line operating point (see EXPERIMENTS.md budget ablation)
    coord.plugin.explorer_config.global_budget = 22;
    coord.plugin.explorer_config.local_budget = 10;

    let t0 = std::time::Instant::now();
    let kermit = coord.run_schedule(&jobs);
    let wall = t0.elapsed();
    let default =
        run_fixed_config(&jobs, default_config_index(), &cfg.engine, 7);
    let rot = run_fixed_config(&jobs, rule_of_thumb(), &cfg.engine, 7);
    let oracle = run_oracle(&jobs, &cfg.engine, 7);

    let mut t = Table::new(&[
        "policy", "makespan(s)", "mean job(s)", "steady state(s)",
        "vs default", "% of oracle",
    ]);
    for (name, r) in [
        ("kermit", &kermit),
        ("default", &default),
        ("rule-of-thumb", &rot),
        ("oracle", &oracle),
    ] {
        t.row(&[
            name.to_string(),
            format!("{:.0}", r.makespan),
            format!("{:.1}", r.mean_duration()),
            format!("{:.1}", r.tail_mean_duration(20)),
            pct(1.0 - r.makespan / default.makespan),
            pct(oracle.tail_mean_duration(20) / r.tail_mean_duration(20)),
        ]);
    }
    t.print();

    println!("\nplugin: {:?}", kermit.plugin_stats);
    println!(
        "workloads known: {}  label consistency: {}",
        kermit.workloads_known,
        pct(kermit.classification_consistency())
    );
    println!(
        "steady-state tuning efficiency vs oracle: {}",
        pct(oracle.tail_mean_duration(20) / kermit.tail_mean_duration(20))
    );
    println!(
        "steady-state gain vs rule-of-thumb: {}",
        pct(1.0 - kermit.tail_mean_duration(20) / rot.tail_mean_duration(20))
    );
    println!("simulation wall-clock: {:.2?}", wall);
}
