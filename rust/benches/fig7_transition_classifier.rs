//! Figure 7 reproduction: TransitionClassifier accuracy on rate-of-
//! change features, with the raw-feature ablation.

use kermit::benchkit::{pct, Table};
use kermit::experiments::fig7;

fn main() {
    println!("\n== Fig 7: TransitionClassifier performance ==");
    println!("paper: random forest on rate-of-change features\n");
    let mut t = Table::new(&[
        "seed", "transition_types", "accuracy(ROC)", "macroF1(ROC)",
        "accuracy(raw ablation)",
    ]);
    let mut accs = Vec::new();
    for seed in [3u64, 11, 29] {
        let r = fig7::run(seed);
        accs.push(r.accuracy_roc);
        t.row(&[
            seed.to_string(),
            r.n_transition_types.to_string(),
            pct(r.accuracy_roc),
            pct(r.f1_roc),
            pct(r.accuracy_raw),
        ]);
    }
    t.print();
    let mean = accs.iter().sum::<f64>() / accs.len() as f64;
    println!("\nmean ROC accuracy: {}", pct(mean));
}
