//! Chaos-lab runner: the standard fault-scenario sweep, each scenario
//! scored against its fault-free oracle for the graceful-degradation
//! guarantees (bounded regret, zero livelocked sessions, poison
//! containment, cache recovery). Writes the deterministic per-scenario
//! JSON snapshots to `CHAOS_outcomes.json` (the CI artifact — a
//! failure reproduces locally from its seed via `KERMIT_CHAOS_SEED`).
//!
//! With `KERMIT_SMOKE=1` the sweep shrinks to toy sizes and *asserts*
//! every scenario passes — the blocking `rust-chaos-smoke` CI job.

use kermit::benchkit::Table;
use kermit::experiments::chaos;
use kermit::util::json::Json;

fn main() {
    let smoke = matches!(
        std::env::var("KERMIT_SMOKE").as_deref(),
        Ok(v) if !v.is_empty() && v != "0"
    );

    println!("\n== Chaos lab (faulted simcluster vs fault-free oracle) ==\n");
    let t0 = std::time::Instant::now();
    let outcomes = chaos::run_all(smoke);
    let wall = t0.elapsed();

    let mut t = Table::new(&[
        "scenario",
        "regret",
        "bound",
        "livelock",
        "quarantined",
        "poison srv",
        "tail hit (o/f)",
        "jobs (o/f)",
        "verdict",
    ]);
    for o in &outcomes {
        t.row(&[
            o.name.clone(),
            format!("{:+.3}", o.regret),
            format!("{:.2}", o.regret_bound),
            format!("{}", o.livelocked_sessions),
            format!("{}", o.labels_quarantined + o.audit_quarantined),
            format!("{}", o.poison_servings),
            format!(
                "{:.0}%/{:.0}%",
                100.0 * o.oracle_tail_hit_ratio,
                100.0 * o.faulted_tail_hit_ratio
            ),
            format!("{}/{}", o.oracle_jobs, o.faulted_jobs),
            if o.pass { "pass".into() } else { "FAIL".into() },
        ]);
        for f in &o.failures {
            println!("{}: FAIL — {f}", o.name);
        }
    }
    t.print();
    println!(
        "\n{} scenarios, wall {:.1}s",
        outcomes.len(),
        wall.as_secs_f64()
    );

    // deterministic JSON snapshots: same seeds → same bytes
    let snapshot =
        Json::Arr(outcomes.iter().map(|o| o.to_json()).collect());
    let path = "CHAOS_outcomes.json";
    match std::fs::write(path, snapshot.encode_pretty()) {
        Ok(()) => println!("snapshots written to {path}"),
        Err(e) => println!("snapshot write failed ({path}): {e}"),
    }

    if smoke {
        for o in &outcomes {
            assert!(
                o.pass,
                "scenario {} violated its degradation guarantees: {:?}",
                o.name, o.failures
            );
        }
        println!("\nchaos smoke OK");
    }
}
