//! Durable-knowledge-plane runner: the crash/recovery scenario sweep
//! (`crash_restart`, `corrupt_snapshot`) over a real tuning plane with
//! seeded I/O fault injection. Scores the crash-consistency
//! guarantees — zero learned-optimum loss up to the WAL tail,
//! quarantine surviving restart, corrupt-snapshot fallback, warm
//! cache hits from job one, bounded cold-start regret — and writes the
//! deterministic per-scenario JSON snapshots to `PERSIST_outcomes.json`
//! (the CI artifact — a failure reproduces locally from its seed via
//! `KERMIT_CHAOS_SEED`).
//!
//! With `KERMIT_SMOKE=1` the sweep shrinks to toy sizes and *asserts*
//! every scenario passes — the blocking `rust-persist-smoke` CI job.

use kermit::benchkit::Table;
use kermit::experiments::chaos;
use kermit::util::json::Json;

fn main() {
    let smoke = matches!(
        std::env::var("KERMIT_SMOKE").as_deref(),
        Ok(v) if !v.is_empty() && v != "0"
    );

    println!("\n== Durable knowledge plane (crash/recovery sweep) ==\n");
    let t0 = std::time::Instant::now();
    let outcomes = chaos::run_persistence(smoke);
    let wall = t0.elapsed();

    let mut t = Table::new(&[
        "scenario",
        "gen",
        "rejected",
        "replayed",
        "torn",
        "optima (crash/rec)",
        "lost",
        "quarantine",
        "warm",
        "regret",
        "verdict",
    ]);
    for o in &outcomes {
        t.row(&[
            o.name.clone(),
            match o.generation_loaded {
                Some(g) => format!("{g}"),
                None => "-".into(),
            },
            format!("{}", o.snapshots_rejected),
            format!("{}", o.wal_records_replayed),
            if o.wal_torn_tail { "yes".into() } else { "no".into() },
            format!("{}/{}", o.optima_at_crash, o.optima_recovered),
            format!("{}", o.lost_optima),
            format!(
                "{}/{}",
                o.quarantined_at_crash, o.quarantined_recovered
            ),
            format!("{}", o.warm_tenants),
            format!("{:+.3}", o.cold_regret),
            if o.pass { "pass".into() } else { "FAIL".into() },
        ]);
        for f in &o.failures {
            println!("{}: FAIL — {f}", o.name);
        }
    }
    t.print();
    println!(
        "\n{} scenarios, wall {:.1}s",
        outcomes.len(),
        wall.as_secs_f64()
    );

    // deterministic JSON snapshots: same seeds → same bytes
    let snapshot =
        Json::Arr(outcomes.iter().map(|o| o.to_json()).collect());
    let path = "PERSIST_outcomes.json";
    match std::fs::write(path, snapshot.encode_pretty()) {
        Ok(()) => println!("snapshots written to {path}"),
        Err(e) => println!("snapshot write failed ({path}): {e}"),
    }

    if smoke {
        for o in &outcomes {
            assert!(
                o.pass,
                "scenario {} violated its recovery guarantees: {:?}",
                o.name, o.failures
            );
        }
        println!("\npersist smoke OK");
    }
}
