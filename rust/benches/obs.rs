//! Telemetry-plane smoke runner: drives a multi-tenant tuning-plane
//! run with telemetry and decision tracing enabled, scrapes the
//! registry, validates the Prometheus exposition with the strict
//! parser, exercises the pool epoch-delta API, and runs the three
//! alert-bearing chaos scenarios to prove the loop-health rules fire
//! under their fault and clear after recovery — while the fault-free
//! oracles stay silent. Writes `OBS_snapshot.json` (registry snapshot,
//! decision-trace timeline, per-scenario alert verdicts — the CI
//! artifact).
//!
//! With `KERMIT_SMOKE=1` everything shrinks to toy sizes and the
//! guarantees are *asserted* — the blocking `rust-obs-smoke` CI job.

use kermit::benchkit::Table;
use kermit::chaoslab::{run_scenario, standard_scenarios};
use kermit::experiments::tuning_plane::{plane_config, schedules, sim_config};
use kermit::linalg::engine::{pool_stats, pool_stats_delta};
use kermit::obs::{parse_prometheus, render_prometheus, snapshot_json, Registry};
use kermit::simcluster::multi::MultiClusterEngine;
use kermit::simcluster::rm::ResourceManager;
use kermit::tuning::TuningPlane;
use kermit::util::json::Json;

fn main() {
    let smoke = matches!(
        std::env::var("KERMIT_SMOKE").as_deref(),
        Ok(v) if !v.is_empty() && v != "0"
    );
    let (tenants, jobs, budget) = if smoke { (3, 8, 8) } else { (4, 12, 14) };
    let seed = 11;

    println!("\n== Telemetry plane (scrape, exposition, alerts, tracing) ==\n");

    // ---- instrumented multi-tenant run --------------------------------
    let mut epoch = pool_stats(); // pool counters are process-global
    let mut plane = TuningPlane::new(plane_config(seed, budget));
    let reg = Registry::new();
    plane.enable_telemetry(&reg);
    plane.enable_tracing(1024);
    let scheds = schedules(seed, tenants, jobs, &[0, 5]);
    let mut engine = MultiClusterEngine::new(
        ResourceManager::default_cluster(),
        sim_config(),
        seed,
    );
    for (t, js) in &scheds {
        plane.ensure_tenant(*t);
        engine.push_jobs(*t, js);
    }
    let t0 = std::time::Instant::now();
    let sim = engine.run(&mut plane);
    plane.drain();
    plane.reconcile(sim.makespan + plane.resilience.decision_timeout + 1.0);
    plane.scrape(&reg);
    // the pool's epoch delta covers exactly this run's executor work
    let pool_delta = pool_stats_delta(&mut epoch);
    pool_delta.export_metrics(&reg);
    let wall_run = t0.elapsed();

    // ---- strict exposition validation ---------------------------------
    let text = render_prometheus(&reg);
    let fams = match parse_prometheus(&text) {
        Ok(f) => f,
        Err(e) => panic!("exposition failed strict parsing: {e}\n{text}"),
    };
    let samples: usize = fams.iter().map(|f| f.samples).sum();
    println!(
        "exposition: {} families, {} samples, strict parse OK \
         (run wall {:.1}s)",
        fams.len(),
        samples,
        wall_run.as_secs_f64()
    );
    for prefix in [
        "kermit_stream_",
        "kermit_plugin_",
        "kermit_tuning_",
        "kermit_coordinator_",
        "kermit_pool_",
    ] {
        assert!(
            fams.iter().any(|f| f.name.starts_with(prefix)),
            "no {prefix} family in the exposition"
        );
    }
    let trace = plane.decision_trace().expect("tracing enabled");
    assert_eq!(trace.open_spans(), 0, "spans left open after reconcile");

    // ---- alert-bearing chaos scenarios --------------------------------
    let mut t = Table::new(&[
        "scenario",
        "expected alerts",
        "fired",
        "cleared",
        "oracle",
        "verdict",
    ]);
    let sweep = standard_scenarios(smoke);
    let mut scenario_snaps = Vec::new();
    let mut all_pass = true;
    for spec in sweep.iter().filter(|s| !s.expect_alerts.is_empty()) {
        let o = run_scenario(spec);
        t.row(&[
            o.name.clone(),
            spec.expect_alerts.join(","),
            o.alerts_fired.join(","),
            o.alerts_cleared.join(","),
            format!("{}", o.oracle_alerts),
            if o.pass { "pass".into() } else { "FAIL".into() },
        ]);
        for f in &o.failures {
            println!("{}: FAIL — {f}", o.name);
        }
        all_pass &= o.pass;
        if smoke {
            for a in &spec.expect_alerts {
                assert!(
                    o.alerts_fired.iter().any(|x| x == a),
                    "{}: expected alert {a} never fired",
                    o.name
                );
                assert!(
                    o.alerts_cleared.iter().any(|x| x == a),
                    "{}: alert {a} did not clear",
                    o.name
                );
            }
            assert_eq!(
                o.oracle_alerts, 0,
                "{}: fault-free oracle fired alerts",
                o.name
            );
        }
        scenario_snaps.push(o.to_json());
    }
    t.print();

    // ---- CI artifact ---------------------------------------------------
    let mut snap = Json::obj();
    snap.set("registry", snapshot_json(&reg))
        .set("decision_trace", trace.timeline_json())
        .set("scenarios", Json::Arr(scenario_snaps));
    let path = "OBS_snapshot.json";
    match std::fs::write(path, snap.encode_pretty()) {
        Ok(()) => println!("\nsnapshot written to {path}"),
        Err(e) => println!("\nsnapshot write failed ({path}): {e}"),
    }

    if smoke {
        assert!(all_pass, "an alert-bearing chaos scenario failed");
        println!("\nobs smoke OK");
    }
}
