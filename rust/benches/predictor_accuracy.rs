//! Workload-type prediction accuracy (paper: up to 96%): the LSTM
//! artifact vs Markov vs persistence, at horizons t+1 / t+5 / t+10.

use kermit::benchkit::{bench, pct, Table};
use kermit::experiments::predictor::{
    run_native, score_predictor, standard_scenario,
};
use kermit::online::predictor::LabelPredictor;
use kermit::runtime::{nn::LstmPredictor, Runtime};

fn main() {
    println!("\n== WorkloadPredictor accuracy (paper §8: up to 96%) ==\n");
    let (train, test) = standard_scenario(5);
    println!(
        "scenario: recurring 5-job rotation with 6% ad-hoc noise; {} train / {} test labels",
        train.len(),
        test.len()
    );

    let mut t = Table::new(&["predictor", "t+1", "t+5", "t+10"]);
    let rows = run_native(&train, &test);
    for name in ["markov", "last_value"] {
        let cells: Vec<String> = [1usize, 5, 10]
            .iter()
            .map(|&h| {
                pct(rows
                    .iter()
                    .find(|r| r.predictor == name && r.horizon == h)
                    .unwrap()
                    .accuracy)
            })
            .collect();
        t.row(&[
            name.to_string(),
            cells[0].clone(),
            cells[1].clone(),
            cells[2].clone(),
        ]);
    }

    match Runtime::load(&Runtime::default_dir()) {
        Ok(rt) => {
            let lstm = LstmPredictor::new(&rt, 0).unwrap();
            let loss = lstm.train_on_sequence(&train, 25, 0.4, 1).unwrap();
            let scores = score_predictor(&lstm, &test);
            t.row(&[
                "lstm (pjrt artifact)".to_string(),
                pct(scores[0].1),
                pct(scores[1].1),
                pct(scores[2].1),
            ]);
            println!("lstm final training loss: {loss:.3}");

            t.print();

            // prediction latency through PJRT (on-line path)
            let hist: Vec<u32> = test[..32.min(test.len())].to_vec();
            let timing = bench(3, 20, || {
                std::hint::black_box(lstm.predict(&hist, 1));
            });
            println!("\nlstm artifact prediction latency: {}", timing.per_iter_str());
        }
        Err(e) => {
            t.print();
            println!("(lstm artifact skipped: {e})");
        }
    }
}
