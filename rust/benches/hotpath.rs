//! Hot-path micro-benchmarks (§Perf): the on-line pipeline stages that
//! must never become the bottleneck — window aggregation, change
//! detection, classification, context publication — plus the contiguous
//! `Matrix` kernels behind Fig-10 discovery and the PJRT execution costs
//! of each artifact.
//!
//! Writes `BENCH_hotpath.json` (stage -> median_ns, plus the rendered
//! table) so the perf trajectory is machine-trackable across PRs.

use kermit::benchkit::{bench, fmt_ns, Table};
use kermit::clustering::kmeans::{kmeans, kmeans_with};
use kermit::clustering::DistanceProvider;
use kermit::clustering::{dbscan, dbscan_with, DbscanConfig, EngineDistance, NativeDistance};
use kermit::experiments::fig6;
use kermit::features::AnalyticWindow;
use kermit::linalg::engine::{self, Engine};
use kermit::linalg::{sq_dist, Matrix};
use kermit::ml::forest::{ForestConfig, RandomForest};
use kermit::ml::Classifier;
use kermit::monitor::{aggregate_samples, MonitorConfig};
use kermit::online::classifier::ForestWindowClassifier;
use kermit::online::{ContextStream, OnlinePipeline};
use kermit::runtime::{literal_f32, shapes, Runtime};
use kermit::stream::{RouterConfig, StreamRouter, TenantId};
use kermit::util::rng::Rng;
use kermit::workloadgen::{tenant_traces, tour_schedule, Generator};
use std::sync::{Arc, Mutex};

/// The old per-call scoped-spawn fan-out PR 2's engine used, kept here
/// as the reference the `spawn_amortization` stage measures the
/// persistent pool against.
fn scoped_for_rows(threads: usize, out: &mut [f64], f: impl Fn(usize, &mut [f64]) + Sync) {
    let items = out.len();
    let workers = threads.min(items).max(1);
    let chunk = items.div_ceil(workers);
    std::thread::scope(|s| {
        let f = &f;
        for (ci, c) in out.chunks_mut(chunk).enumerate() {
            s.spawn(move || f(ci * chunk, c));
        }
    });
}

/// Pairwise matrix forced through the scalar kernel (upper triangle +
/// mirror, like the sequential provider) — the reference row for the
/// kernel-tier comparison.
fn pairwise_scalar_kernel(rows: &Matrix) -> Vec<f64> {
    let n = rows.n_rows();
    let mut out = vec![0.0; n * n];
    for i in 0..n {
        let ri = rows.row(i);
        for j in (i + 1)..n {
            let d = engine::sq_dist_scalar(ri, rows.row(j));
            out[i * n + j] = d;
            out[j * n + i] = d;
        }
    }
    out
}

fn main() {
    println!("\n== Hot-path micro-benchmarks (§Perf) ==\n");
    let mut t = Table::new(&["stage", "latency", "throughput"]);

    // --- window aggregation (KWmon)
    let mut g = Generator::with_default_config(0);
    let trace = g.generate(&tour_schedule(3000, &[0, 2]));
    let mcfg = MonitorConfig { window_size: 30 };
    let tm = bench(3, 20, || {
        std::hint::black_box(aggregate_samples(&trace.samples, &mcfg));
    });

    t.timed_row(
        &[
            "aggregate 6k samples -> 200 windows".into(),
            tm.per_iter_str(),
            format!(
                "{:.1}M samples/s",
                trace.len() as f64 / (tm.median_ns / 1e9) / 1e6
            ),
        ],
        tm,
    );

    // --- full online pipeline per window (detector+forest+predictor)
    let data = fig6::data(42);
    let mut rng = Rng::new(7);
    let forest =
        RandomForest::fit(&data.train, ForestConfig::default(), &mut rng);
    let ctx = Arc::new(Mutex::new(ContextStream::new(64)));
    let mut pipe = OnlinePipeline::new(ctx);
    pipe.set_classifier(Box::new(ForestWindowClassifier::new(
        forest.clone(),
        0.5,
    )));
    let windows = aggregate_samples(&trace.samples, &mcfg);
    let mut i = 0usize;
    let tp = bench(50, 2000, || {
        std::hint::black_box(pipe.observe(&windows[i % windows.len()]));
        i += 1;
    });
    t.timed_row(
        &[
            "online pipeline observe(window)".into(),
            tp.per_iter_str(),
            format!("{:.0}k windows/s", 1e9 / tp.median_ns / 1e3),
        ],
        tp,
    );

    // --- same observe path with telemetry installed: ObserveMetrics
    // adds at most three relaxed atomic increments per window, so the
    // instrumented stage must stay within ~3% of the one above
    let obs_reg = kermit::obs::Registry::new();
    let obs_ctx = Arc::new(Mutex::new(ContextStream::new(64)));
    let mut pipe_obs = OnlinePipeline::new(obs_ctx);
    pipe_obs.set_classifier(Box::new(ForestWindowClassifier::new(
        forest.clone(),
        0.5,
    )));
    pipe_obs.set_observe_metrics(kermit::obs::ObserveMetrics::register(
        &obs_reg, "0",
    ));
    let mut io = 0usize;
    let tpo = bench(50, 2000, || {
        std::hint::black_box(pipe_obs.observe(&windows[io % windows.len()]));
        io += 1;
    });
    t.row(&[
        "observe_instrumented".into(),
        tpo.per_iter_str(),
        format!(
            "{:+.1}% vs uninstrumented",
            (tpo.median_ns / tp.median_ns - 1.0) * 100.0
        ),
    ]);
    t.metric("observe_uninstrumented", tp.median_ns);
    t.metric("observe_instrumented", tpo.median_ns);

    // --- forest inference alone
    let probe = AnalyticWindow::from_observation(&windows[0]).features;
    let tf = bench(50, 2000, || {
        std::hint::black_box(forest.predict(&probe));
    });
    t.timed_row(
        &[
            "random forest predict".into(),
            tf.per_iter_str(),
            format!("{:.0}k preds/s", 1e9 / tf.median_ns / 1e3),
        ],
        tf,
    );

    // --- contiguous Matrix kernels (Fig-10 discovery path)
    let mut krng = Rng::new(3);
    let disc = {
        let mut m = Matrix::with_width(shapes::ANALYTIC_FEATURES);
        let mut buf = vec![0.0; shapes::ANALYTIC_FEATURES];
        for r in 0..600 {
            for (j, b) in buf.iter_mut().enumerate() {
                *b = ((r % 6) * 10) as f64
                    + krng.normal() * 0.5
                    + j as f64 * 0.01;
            }
            m.push_row(&buf);
        }
        m
    };
    // scalar vs simd kernel, then sequential vs parallel stages for
    // every discovery hot path — the engine rows quantify the speedup
    // the coordinator gets from `DiscoveryConfig::engine`
    let eng = Engine::auto();

    let (ra, rb) = (disc.row(0).to_vec(), disc.row(300).to_vec());
    let ts_scalar = bench(100, 5000, || {
        std::hint::black_box(engine::sq_dist_scalar(&ra, &rb));
    });
    t.timed_row(
        &[
            format!("sq_dist {}-wide row (scalar)", shapes::ANALYTIC_FEATURES),
            ts_scalar.per_iter_str(),
            format!("{:.0}M dists/s", 1e9 / ts_scalar.median_ns / 1e6),
        ],
        ts_scalar,
    );
    let ts_simd = bench(100, 5000, || {
        std::hint::black_box(sq_dist(&ra, &rb));
    });
    t.timed_row(
        &[
            format!("sq_dist {}-wide row (simd)", shapes::ANALYTIC_FEATURES),
            ts_simd.per_iter_str(),
            format!("{:.0}M dists/s", 1e9 / ts_simd.median_ns / 1e6),
        ],
        ts_simd,
    );

    let pairs_rate = |ns: f64| {
        format!("{:.1}M pairs/s", (600.0 * 600.0) / (ns / 1e9) / 1e6)
    };
    // scalar-kernel reference pairwise: together with the dispatch-
    // kernel stages below (whose active tier is in `meta.simd_tier`)
    // this records the scalar / simd / simd-fast pairwise comparison —
    // run the bench once per feature set to fill in all three tiers
    let tps = bench(2, 10, || {
        std::hint::black_box(pairwise_scalar_kernel(&disc));
    });
    t.timed_row(
        &[
            "pairwise_sq 600x32 (scalar kernel)".into(),
            tps.per_iter_str(),
            pairs_rate(tps.median_ns),
        ],
        tps,
    );
    let td = bench(2, 10, || {
        std::hint::black_box(NativeDistance.pairwise_sq(&disc));
    });
    t.timed_row(
        &[
            "pairwise_sq 600x32 (sequential)".into(),
            td.per_iter_str(),
            pairs_rate(td.median_ns),
        ],
        td,
    );
    let par_dist = EngineDistance::new(eng);
    let tdp = bench(2, 10, || {
        std::hint::black_box(par_dist.pairwise_sq(&disc));
    });
    t.timed_row(
        &[
            "pairwise_sq 600x32 (parallel)".into(),
            tdp.per_iter_str(),
            pairs_rate(tdp.median_ns),
        ],
        tdp,
    );

    let db_cfg = DbscanConfig { eps: 10.0, min_pts: 4 };
    let tdb = bench(2, 10, || {
        std::hint::black_box(dbscan(&disc, &db_cfg, &NativeDistance));
    });
    t.timed_row(
        &[
            "dbscan 600 windows (sequential)".into(),
            tdb.per_iter_str(),
            "-".into(),
        ],
        tdb,
    );
    let tdbp = bench(2, 10, || {
        std::hint::black_box(dbscan_with(eng, &disc, &db_cfg, &par_dist));
    });
    t.timed_row(
        &[
            "dbscan 600 windows (parallel)".into(),
            tdbp.per_iter_str(),
            "-".into(),
        ],
        tdbp,
    );

    let mut kmrng = Rng::new(9);
    let tk = bench(2, 10, || {
        std::hint::black_box(kmeans(&disc, 6, 50, &mut kmrng));
    });
    t.timed_row(
        &[
            "kmeans assign k=6 600 windows (sequential)".into(),
            tk.per_iter_str(),
            "-".into(),
        ],
        tk,
    );
    let mut kmrng_p = Rng::new(9);
    let tkp = bench(2, 10, || {
        std::hint::black_box(kmeans_with(eng, &disc, 6, 50, &mut kmrng_p));
    });
    t.timed_row(
        &[
            "kmeans assign k=6 600 windows (parallel)".into(),
            tkp.per_iter_str(),
            "-".into(),
        ],
        tkp,
    );

    let batch_rate = |ns: f64| {
        format!("{:.0}k preds/s", disc.n_rows() as f64 / (ns / 1e9) / 1e3)
    };
    let tb = bench(3, 30, || {
        std::hint::black_box(forest.predict_batch(&disc));
    });
    t.timed_row(
        &[
            "predict_batch 600 windows (sequential)".into(),
            tb.per_iter_str(),
            batch_rate(tb.median_ns),
        ],
        tb,
    );
    let tbp = bench(3, 30, || {
        std::hint::black_box(forest.predict_batch_with(eng, &disc));
    });
    t.timed_row(
        &[
            "predict_batch 600 windows (parallel)".into(),
            tbp.per_iter_str(),
            batch_rate(tbp.median_ns),
        ],
        tbp,
    );

    // --- spawn amortization: 1k tiny dispatches through the old
    // scoped-spawn fan-out vs the persistent pool. Small batches (96
    // f64 items) make the dispatch overhead itself the measurand: the
    // pool's condvar wakeup must beat a thread spawn+join per call
    // (this is the per-merge agglomerative / per-tick router pattern).
    let tiny_items = 96usize;
    let dispatches = 1000usize;
    let amort_engine = Engine::with_threads(eng.threads()).with_min_items(1);
    let mut tiny = vec![0.0f64; tiny_items];
    let per_dispatch = |ns: f64| format!("{}/dispatch", fmt_ns(ns / dispatches as f64));
    let t_scoped = bench(1, 5, || {
        for _ in 0..dispatches {
            scoped_for_rows(eng.threads(), &mut tiny, |start, chunk| {
                for (off, cell) in chunk.iter_mut().enumerate() {
                    *cell = ((start + off) as f64).sqrt();
                }
            });
            std::hint::black_box(&mut tiny);
        }
    });
    t.row(&[
        format!("spawn_amortization {dispatches}x{tiny_items} (scoped spawn)"),
        t_scoped.per_iter_str(),
        per_dispatch(t_scoped.median_ns),
    ]);
    t.metric("spawn_amortization_scoped", t_scoped.median_ns);
    let t_pool = bench(1, 5, || {
        for _ in 0..dispatches {
            amort_engine.for_rows(&mut tiny, 1, |start, chunk| {
                for (off, cell) in chunk.iter_mut().enumerate() {
                    *cell = ((start + off) as f64).sqrt();
                }
            });
            std::hint::black_box(&mut tiny);
        }
    });
    t.row(&[
        format!("spawn_amortization {dispatches}x{tiny_items} (persistent pool)"),
        t_pool.per_iter_str(),
        per_dispatch(t_pool.median_ns),
    ]);
    t.metric("spawn_amortization_pool", t_pool.median_ns);

    // --- multi-tenant observe path: K pipeline shards per tick,
    // sequential vs engine-parallel dispatch (the stream layer's win —
    // the acceptance bar is engine >= seq throughput at >= 4 tenants)
    let tenants = 8usize;
    let per_tick = 48usize; // windows per tenant per tick
    let tenant_trs =
        tenant_traces(17, tenants, 3, per_tick * 30, &[0, 1, 2, 3, 4, 5], 0, 0.0);
    let tenant_windows: Vec<Vec<_>> = tenant_trs
        .iter()
        .map(|tr| {
            let mut ws = aggregate_samples(&tr.samples, &mcfg);
            ws.truncate(per_tick);
            ws
        })
        .collect();
    let mt_rate = |ns: f64| {
        format!(
            "{:.0}k windows/s",
            (tenants * per_tick) as f64 / (ns / 1e9) / 1e3
        )
    };
    let mut run_router = |engine: Engine, stage: &str| {
        let mut router = StreamRouter::new(RouterConfig {
            monitor: mcfg.clone(),
            context_cap: 64,
            engine,
            ..Default::default()
        });
        for k in 0..tenant_windows.len() {
            router
                .add_tenant(TenantId(k as u32))
                .pipeline
                .set_classifier(Box::new(ForestWindowClassifier::new(
                    forest.clone(),
                    0.5,
                )));
        }
        let tm = bench(2, 12, || {
            for (k, ws) in tenant_windows.iter().enumerate() {
                router.enqueue_windows(TenantId(k as u32), ws);
            }
            std::hint::black_box(router.tick());
        });
        // display row with the parameters, but record the JSON metric
        // once under the stable short key only — bench_diff must keep
        // matching the stage across parameter changes
        t.row(&[
            format!("{stage} ({tenants} tenants x {per_tick} windows)"),
            tm.per_iter_str(),
            mt_rate(tm.median_ns),
        ]);
        t.metric(stage, tm.median_ns);
    };
    run_router(Engine::sequential(), "observe_multitenant_seq");
    run_router(eng, "observe_multitenant_engine");

    // --- plugin decision micro: Algorithm 1's steady-state path (the
    // cache hit every recurring job takes) — one context read + one
    // read-locked DB lookup; must stay far below the observe path
    let decide_db = {
        let mut db = kermit::knowledge::WorkloadDb::new();
        let rows: Vec<Vec<f64>> = vec![vec![1.0; 4], vec![1.1; 4]];
        let label = db.insert_new(
            kermit::knowledge::Characterization::from_vec_rows(&rows),
            vec![1.05; 4],
            2,
            false,
        );
        db.set_optimal_config(
            label,
            kermit::simcluster::default_config_index(),
        );
        (Arc::new(std::sync::RwLock::new(db)), label)
    };
    let (decide_db, decide_label) = decide_db;
    let decide_ctx = Arc::new(Mutex::new(ContextStream::new(16)));
    let mut plugin =
        kermit::online::KermitPlugin::new(decide_db, decide_ctx);
    let tdec = bench(100, 5000, || {
        std::hint::black_box(
            plugin.choose_config_for_label(decide_label),
        );
    });
    t.timed_row(
        &[
            "plugin_decision".into(),
            tdec.per_iter_str(),
            format!("{:.1}M decisions/s", 1e9 / tdec.median_ns / 1e6),
        ],
        tdec,
    );

    // --- tuning plane end-to-end: K=4 tenants' job streams through the
    // shared simcluster with per-tenant plug-ins, adaptive cadence and
    // the consolidated off-line cycle — the closed-loop macro stage
    let tp_tenants = 4usize;
    let tp_jobs = 6usize;
    let tp_scheds = kermit::experiments::tuning_plane::schedules(
        17, tp_tenants, tp_jobs, &[0, 5],
    );
    let ttp = bench(1, 3, || {
        std::hint::black_box(kermit::experiments::tuning_plane::run_shared(
            17, &tp_scheds, 8,
        ));
    });
    t.row(&[
        format!("tuning_plane_k4 ({tp_tenants} tenants x {tp_jobs} jobs)"),
        ttp.per_iter_str(),
        format!(
            "{:.1} jobs/s",
            (tp_tenants * tp_jobs) as f64 / (ttp.median_ns / 1e9)
        ),
    ]);
    t.metric("tuning_plane_k4", ttp.median_ns);

    // --- knowledge snapshot load: the warm-start cost of the durable
    // knowledge plane — verify + decode + rebuild a ~200-entry binary
    // snapshot, the price a restarted plane pays before its first job
    let snap_entries = 200usize;
    let snap_dir = std::env::temp_dir().join("kermit_hotpath_snapshot");
    std::fs::remove_dir_all(&snap_dir).ok();
    {
        let mut db = kermit::knowledge::WorkloadDb::new();
        let mut rng = Rng::new(99);
        for _ in 0..snap_entries {
            let rows: Vec<Vec<f64>> = (0..3)
                .map(|_| {
                    (0..8).map(|_| rng.range_f64(0.0, 10.0)).collect()
                })
                .collect();
            let centroid: Vec<f64> =
                (0..8).map(|_| rng.range_f64(0.0, 10.0)).collect();
            let label = db.insert_new(
                kermit::knowledge::Characterization::from_vec_rows(&rows),
                centroid,
                3,
                false,
            );
            db.set_optimal_config(
                label,
                kermit::simcluster::default_config_index(),
            );
        }
        let (mut store, _, _) = kermit::knowledge::KnowledgeStore::open(
            &snap_dir,
            Box::new(kermit::knowledge::BinaryCodec),
        )
        .unwrap();
        store.snapshot(&db).unwrap();
    }
    let tsl = bench(5, 40, || {
        let (_, db, _) = kermit::knowledge::KnowledgeStore::open(
            &snap_dir,
            Box::new(kermit::knowledge::BinaryCodec),
        )
        .unwrap();
        std::hint::black_box(db.len());
    });
    t.row(&[
        format!("knowledge_snapshot_load ({snap_entries} entries)"),
        tsl.per_iter_str(),
        format!(
            "{:.0}k entries/s",
            snap_entries as f64 / (tsl.median_ns / 1e9) / 1e3
        ),
    ]);
    t.metric("knowledge_snapshot_load", tsl.median_ns);
    std::fs::remove_dir_all(&snap_dir).ok();

    t.print();

    // --- PJRT artifact execution costs
    println!("\n-- PJRT artifact execution --");
    match Runtime::load(&Runtime::default_dir()) {
        Ok(rt) => {
            let mut t2 = Table::new(&["artifact", "exec latency"]);
            let mut rng = Rng::new(1);
            // pairwise_dist
            let n = shapes::DIST_N;
            let f = shapes::DIST_F;
            let x: Vec<f64> =
                (0..n * f).map(|_| rng.range_f64(0.0, 10.0)).collect();
            let art = rt.get("pairwise_dist").unwrap();
            let lx = literal_f32(&x, &[n as i64, f as i64]).unwrap();
            let ly = literal_f32(&x, &[n as i64, f as i64]).unwrap();
            let td = bench(3, 20, || {
                std::hint::black_box(
                    art.run(&[lx.clone(), ly.clone()]).unwrap(),
                );
            });
            t2.timed_row(
                &["pairwise_dist 256x256".into(), td.per_iter_str()],
                td,
            );

            // welch_stats
            let (w, s, nf) = (
                shapes::WELCH_WINDOWS,
                shapes::WELCH_SAMPLES,
                shapes::NUM_FEATURES,
            );
            let xs: Vec<f64> =
                (0..w * s * nf).map(|_| rng.normal_ms(5.0, 2.0)).collect();
            let art = rt.get("welch_stats").unwrap();
            let lw =
                literal_f32(&xs, &[w as i64, s as i64, nf as i64]).unwrap();
            let tw = bench(3, 20, || {
                std::hint::black_box(art.run(&[lw.clone()]).unwrap());
            });
            t2.timed_row(
                &["welch_stats 64 windows".into(), tw.per_iter_str()],
                tw,
            );
            t2.print();
            println!(
                "\nper-window amortized welch via artifact: {}",
                fmt_ns(tw.median_ns / w as f64)
            );
            // fold the artifact numbers into the same JSON
            t.metric("pjrt pairwise_dist 256x256", td.median_ns);
            t.metric("pjrt welch_stats 64 windows", tw.median_ns);
        }
        Err(e) => println!("(artifacts skipped: {e})"),
    }

    // environment metadata so successive PRs diff baselines
    // apples-to-apples (a 2-thread run is not a 16-thread run)
    t.meta("engine_threads", &eng.threads().to_string());
    t.meta("engine_pool", "work-stealing");
    t.meta("simd_feature", if cfg!(feature = "simd") { "on" } else { "off" });
    t.meta(
        "simd_fast_feature",
        if cfg!(feature = "simd-fast") { "on" } else { "off" },
    );
    t.meta("simd_tier", engine::simd_tier());
    t.meta("simd_active", if engine::simd_active() { "yes" } else { "no" });
    t.meta(
        "runtime_artifacts_feature",
        if cfg!(feature = "runtime-artifacts") { "on" } else { "off" },
    );
    t.meta("tuning_plane_tenants", &tp_tenants.to_string());
    t.meta("tuning_plane_jobs", &tp_jobs.to_string());

    let out = std::path::Path::new("BENCH_hotpath.json");
    match t.write_json(out) {
        Ok(()) => println!("\nwrote {}", out.display()),
        Err(e) => println!("\nfailed to write {}: {e}", out.display()),
    }
}
