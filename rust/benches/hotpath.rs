//! Hot-path micro-benchmarks (§Perf): the on-line pipeline stages that
//! must never become the bottleneck — window aggregation, change
//! detection, classification, context publication — plus the PJRT
//! execution costs of each artifact.

use kermit::benchkit::{bench, fmt_ns, Table};
use kermit::experiments::fig6;
use kermit::features::AnalyticWindow;
use kermit::ml::forest::{ForestConfig, RandomForest};
use kermit::ml::Classifier;
use kermit::monitor::{aggregate_samples, MonitorConfig};
use kermit::online::{ContextStream, OnlinePipeline};
use kermit::online::classifier::ForestWindowClassifier;
use kermit::runtime::{literal_f32, shapes, Runtime};
use kermit::util::rng::Rng;
use kermit::workloadgen::{tour_schedule, Generator};
use std::sync::{Arc, Mutex};

fn main() {
    println!("\n== Hot-path micro-benchmarks (§Perf) ==\n");
    let mut t = Table::new(&["stage", "latency", "throughput"]);

    // --- window aggregation (KWmon)
    let mut g = Generator::with_default_config(0);
    let trace = g.generate(&tour_schedule(3000, &[0, 2]));
    let mcfg = MonitorConfig { window_size: 30 };
    let tm = bench(3, 20, || {
        std::hint::black_box(aggregate_samples(&trace.samples, &mcfg));
    });

    t.row(&[
        "aggregate 6k samples -> 200 windows".into(),
        tm.per_iter_str(),
        format!(
            "{:.1}M samples/s",
            trace.len() as f64 / (tm.median_ns / 1e9) / 1e6
        ),
    ]);

    // --- full online pipeline per window (detector+forest+predictor)
    let data = fig6::data(42);
    let mut rng = Rng::new(7);
    let forest =
        RandomForest::fit(&data.train, ForestConfig::default(), &mut rng);
    let ctx = Arc::new(Mutex::new(ContextStream::new(64)));
    let mut pipe = OnlinePipeline::new(ctx);
    pipe.set_classifier(Box::new(ForestWindowClassifier::new(
        forest.clone(),
        0.5,
    )));
    let windows = aggregate_samples(&trace.samples, &mcfg);
    let mut i = 0usize;
    let tp = bench(50, 2000, || {
        std::hint::black_box(pipe.observe(&windows[i % windows.len()]));
        i += 1;
    });
    t.row(&[
        "online pipeline observe(window)".into(),
        tp.per_iter_str(),
        format!("{:.0}k windows/s", 1e9 / tp.median_ns / 1e3),
    ]);

    // --- forest inference alone
    let probe = AnalyticWindow::from_observation(&windows[0]).features;
    let tf = bench(50, 2000, || {
        std::hint::black_box(forest.predict(&probe));
    });
    t.row(&[
        "random forest predict".into(),
        tf.per_iter_str(),
        format!("{:.0}k preds/s", 1e9 / tf.median_ns / 1e3),
    ]);

    t.print();

    // --- PJRT artifact execution costs
    println!("\n-- PJRT artifact execution --");
    match Runtime::load(&Runtime::default_dir()) {
        Ok(rt) => {
            let mut t2 = Table::new(&["artifact", "exec latency"]);
            let mut rng = Rng::new(1);
            // pairwise_dist
            let n = shapes::DIST_N;
            let f = shapes::DIST_F;
            let x: Vec<f64> =
                (0..n * f).map(|_| rng.range_f64(0.0, 10.0)).collect();
            let art = rt.get("pairwise_dist").unwrap();
            let lx = literal_f32(&x, &[n as i64, f as i64]).unwrap();
            let ly = literal_f32(&x, &[n as i64, f as i64]).unwrap();
            let td = bench(3, 20, || {
                std::hint::black_box(
                    art.run(&[lx.clone(), ly.clone()]).unwrap(),
                );
            });
            t2.row(&["pairwise_dist 256x256".into(), td.per_iter_str()]);

            // welch_stats
            let (w, s, nf) = (
                shapes::WELCH_WINDOWS,
                shapes::WELCH_SAMPLES,
                shapes::NUM_FEATURES,
            );
            let xs: Vec<f64> =
                (0..w * s * nf).map(|_| rng.normal_ms(5.0, 2.0)).collect();
            let art = rt.get("welch_stats").unwrap();
            let lw =
                literal_f32(&xs, &[w as i64, s as i64, nf as i64]).unwrap();
            let tw = bench(3, 20, || {
                std::hint::black_box(art.run(&[lw.clone()]).unwrap());
            });
            t2.row(&["welch_stats 64 windows".into(), tw.per_iter_str()]);
            t2.print();
            println!(
                "\nper-window amortized welch via artifact: {}",
                fmt_ns(tw.median_ns / w as f64)
            );
        }
        Err(e) => println!("(artifacts skipped: {e})"),
    }
}
