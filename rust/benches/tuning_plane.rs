//! Tuning-plane experiment runner: K tenants' job streams on one
//! simulated cluster with the full per-tenant MAPE-K loop closed, vs
//! the vendor-default baseline and vs K independent single-tenant
//! loops (probes saved).
//!
//! With `KERMIT_SMOKE=1` the run shrinks to toy sizes and asserts the
//! core invariants — the blocking CI smoke job for the tuning plane.

use kermit::benchkit::Table;
use kermit::experiments::tuning_plane;

fn main() {
    let smoke = matches!(
        std::env::var("KERMIT_SMOKE").as_deref(),
        Ok(v) if !v.is_empty() && v != "0"
    );
    let (tenants_list, jobs): (&[usize], usize) =
        if smoke { (&[4], 12) } else { (&[2, 4, 8], 24) };

    println!("\n== Per-tenant tuning plane (K tenants, shared cluster) ==\n");
    let mut t = Table::new(&[
        "tenants",
        "tuned makespan(s)",
        "default makespan(s)",
        "speedup",
        "cache-hit",
        "x-tenant hits",
        "probes shared",
        "probes indep",
        "saved/tenant",
    ]);
    for &k in tenants_list {
        let t0 = std::time::Instant::now();
        let s = tuning_plane::run(11, k, jobs);
        let wall = t0.elapsed();
        t.row(&[
            format!("{k}"),
            format!("{:.0}", s.tuned_makespan),
            format!("{:.0}", s.default_makespan),
            format!("{:.2}x", s.speedup),
            format!("{:.0}%", 100.0 * s.cache_hit_ratio),
            format!("{}", s.cross_tenant_hits),
            format!("{}", s.probes_shared),
            format!("{}", s.probes_independent),
            format!("{:.1}", s.probes_saved_per_tenant()),
        ]);
        println!(
            "k={k}: {} workloads known, {} offline cycles, peak \
             concurrency {}, wall {:.1}s",
            s.workloads_known,
            s.offline_runs,
            s.peak_concurrency,
            wall.as_secs_f64()
        );
        if smoke {
            // blocking CI invariants (deterministic seeds)
            assert!(s.speedup > 1.0, "tuned lost to default: {s:?}");
            assert!(
                s.cross_tenant_hits >= 1,
                "no cross-tenant optimum reuse: {s:?}"
            );
            assert!(
                s.probes_shared < s.probes_independent,
                "sharing saved no probes: {s:?}"
            );
            assert!(s.peak_concurrency >= 2, "streams never overlapped");
        }
    }
    t.print();
    if smoke {
        println!("\ntuning-plane smoke OK");
    }
}
