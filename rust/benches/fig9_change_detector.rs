//! Figure 9 reproduction: ChangeDetector accuracy (paper: up to 99%),
//! swept over significance level and window size, plus detector
//! latency on the streaming path.

use kermit::benchkit::{bench, pct, Table};
use kermit::experiments::fig9;
use kermit::features::{ObservationWindow, NUM_FEATURES};
use kermit::online::change_detector::{ChangeDetector, ChangeDetectorConfig};

fn main() {
    println!("\n== Fig 9: ChangeDetector performance ==");
    println!("paper: detect workload changes with up to 99% accuracy\n");
    let rows = fig9::run(11);
    let mut t = Table::new(&[
        "window", "alpha", "accuracy", "precision", "recall",
    ]);
    let mut best = 0.0f64;
    for r in &rows {
        best = best.max(r.accuracy);
        t.row(&[
            r.window_size.to_string(),
            format!("{:.0e}", r.alpha),
            pct(r.accuracy),
            pct(r.precision),
            pct(r.recall),
        ]);
    }
    t.print();
    println!("\nbest accuracy: {} (paper: up to 99%)", pct(best));

    // streaming latency per window (hot path)
    let w = |i: u64, level: f64| ObservationWindow {
        index: i,
        time: i as f64,
        samples: 30,
        mean: [level; NUM_FEATURES],
        var: [1.0; NUM_FEATURES],
        truth: None,
    };
    let mut det = ChangeDetector::new(ChangeDetectorConfig::default());
    let mut i = 0u64;
    let timing = bench(100, 1000, || {
        det.observe(&w(i, if i % 10 < 5 { 5.0 } else { 50.0 }));
        i += 1;
    });
    println!("detector latency per window: {}", timing.per_iter_str());
}
