//! Figure 10 reproduction: workload discovery quality (Awt + Purity)
//! for DBSCAN vs k-means vs agglomerative, native and artifact-backed
//! distance paths.

use kermit::benchkit::{bench, pct, Table};
use kermit::clustering::{dbscan, DbscanConfig, NativeDistance};
use kermit::experiments::fig10;
use kermit::runtime::{nn::ArtifactDistance, Runtime};

fn main() {
    println!("\n== Fig 10: workload discovery (clustering) quality ==");
    println!("paper: Awt + Purity per algorithm; DBSCAN is KERMIT's choice\n");
    let mut t = Table::new(&[
        "algorithm", "Awt", "Purity", "clusters", "true_classes",
    ]);
    for r in fig10::run(17) {
        t.row(&[
            r.algorithm.to_string(),
            pct(r.awt),
            pct(r.purity),
            r.clusters_found.to_string(),
            r.true_classes.to_string(),
        ]);
    }
    t.print();

    // artifact-backed DBSCAN (pallas pairwise_dist kernel through PJRT)
    match Runtime::load(&Runtime::default_dir()) {
        Ok(rt) => {
            let ad = ArtifactDistance::new(&rt).unwrap();
            let rows = fig10::run_with_distance(17, &ad);
            let db = rows.iter().find(|r| r.algorithm == "dbscan").unwrap();
            println!(
                "\ndbscan w/ pallas pairwise_dist artifact: Awt {} Purity {}",
                pct(db.awt),
                pct(db.purity)
            );

            // timing: native vs artifact distance on a discovery batch
            let (rows_data, _) = fig10::discovery_data(17, &[0, 2, 3, 5]);
            let tn = bench(1, 5, || {
                std::hint::black_box(dbscan(
                    &rows_data,
                    &DbscanConfig { eps: 10.0, min_pts: 4 },
                    &NativeDistance,
                ));
            });
            let ta = bench(1, 5, || {
                std::hint::black_box(dbscan(
                    &rows_data,
                    &DbscanConfig { eps: 10.0, min_pts: 4 },
                    &ad,
                ));
            });
            println!(
                "dbscan on {} windows: native {} | artifact {}",
                rows_data.n_rows(),
                tn.per_iter_str(),
                ta.per_iter_str()
            );
        }
        Err(e) => println!("(artifact path skipped: {e})"),
    }
}
