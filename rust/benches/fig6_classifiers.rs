//! Figure 6 reproduction: workload-classification accuracy across ML
//! algorithms, plus the MLP artifact variant (PJRT path) and per-
//! algorithm inference timing.

use kermit::benchkit::{bench, pct, Table};
use kermit::experiments::fig6;
use kermit::ml::forest::{ForestConfig, RandomForest};
use kermit::ml::{accuracy, Classifier};
use kermit::online::classifier::WindowClassifier;
use kermit::runtime::{nn::MlpClassifier, Runtime};
use kermit::util::rng::Rng;

fn main() {
    println!("\n== Fig 6: workload classification accuracy by algorithm ==");
    println!("paper: random forest best, ~90%+ accuracy\n");
    let data = fig6::data(42);
    println!(
        "dataset: {} train / {} test windows, {} classes",
        data.train.len(),
        data.test.len(),
        data.train.classes().len()
    );

    let rows = fig6::run(&data, 42);
    let mut t = Table::new(&["algorithm", "accuracy", "macro_f1"]);
    for r in &rows {
        t.row(&[r.algorithm.to_string(), pct(r.accuracy), pct(r.macro_f1)]);
    }

    // MLP artifact variant (the NN comparator through PJRT)
    match Runtime::load(&Runtime::default_dir()) {
        Ok(rt) => {
            let mlp = MlpClassifier::new(&rt, 0).unwrap();
            mlp.fit(&data.train, 30, 0.05, 1).unwrap();
            let preds: Vec<u32> = data
                .test
                .x()
                .iter_rows()
                .map(|r| mlp.classify(r))
                .collect();
            let acc = accuracy(&data.test.labels, &preds);
            t.row(&["mlp (pjrt artifact)".into(), pct(acc), "-".into()]);
        }
        Err(e) => println!("(mlp artifact skipped: {e})"),
    }
    t.print();

    // inference timing: the on-line hot path
    println!("\n-- inference latency (single window) --");
    let mut rng = Rng::new(7);
    let forest =
        RandomForest::fit(&data.train, ForestConfig::default(), &mut rng);
    let probe = data.test.row(0).to_vec();
    let timing = bench(10, 100, || {
        std::hint::black_box(forest.predict(&probe));
    });
    println!("  random forest: {}", timing.per_iter_str());
}
