//! Ingest front-end stress bench: heavy-tailed multi-tenant load
//! against the event-driven [`kermit::stream::IngestFrontEnd`].
//!
//! Drives a Zipf-popularity, bursty-arrival sample stream (10k tenants
//! in the full run) from several producer threads into bounded
//! per-tenant queues while the main thread pumps batches through a
//! [`kermit::stream::StreamRouter`], once per backpressure policy.
//! Records windows/sec, enqueue-latency percentiles, shed counts, and
//! the work-stealing executor's self-metrics (steals, parks, spawn
//! latency) into `BENCH_ingest.json`.
//!
//! `KERMIT_SMOKE=1` shrinks the load for CI and turns on the zero-
//! silent-loss assertions: per-tenant
//! `accepted + shed + resident == submitted` for every policy, zero
//! shed under `Block`, full sample-to-window reconciliation, and the
//! executor demonstrably fanning out when the engine is multi-threaded.

use std::time::{Duration, Instant};

use kermit::benchkit::{fmt_ns, Table};
use kermit::linalg::engine::{self, Engine};
use kermit::monitor::MonitorConfig;
use kermit::stream::{
    IngestConfig, IngestFrontEnd, RouterConfig, ShedPolicy, StreamRouter,
    TenantId,
};
use kermit::workloadgen::{heavy_tailed_stream, Sample};

struct StageOutcome {
    wall_ns: f64,
    windows: u64,
    submitted: u64,
    accepted: u64,
    shed: u64,
    p50_ns: u64,
    p99_ns: u64,
    steals: u64,
    parks: u64,
    executed: u64,
}

/// One full stress pass under `policy`: `nprod` producer threads
/// submitting the event stream through cloned [`IngestHandle`]s while
/// the calling thread pumps the front-end into a fresh router until the
/// producers finish and the queues drain dry.
///
/// [`IngestHandle`]: kermit::stream::IngestHandle
fn run_stage(
    label: &str,
    policy: ShedPolicy,
    events: &[(TenantId, Sample)],
    wsize: usize,
    qcap: usize,
    nprod: usize,
    eng: Engine,
) -> StageOutcome {
    let monitor = MonitorConfig { window_size: wsize };
    let mut fe = IngestFrontEnd::new(IngestConfig {
        queue_cap: qcap,
        policy,
        monitor: monitor.clone(),
        drain_max: 0,
        engine: eng,
        ..IngestConfig::default()
    });
    let mut router = StreamRouter::new(RouterConfig {
        monitor,
        engine: eng,
        ..RouterConfig::default()
    });
    let handle = fe.handle();

    let p0 = engine::pool_stats();
    let mut windows = 0u64;
    let t0 = Instant::now();
    let mut lat: Vec<u64> = std::thread::scope(|s| {
        let producers: Vec<_> = (0..nprod)
            .map(|p| {
                let h = handle.clone();
                s.spawn(move || {
                    let mut lats =
                        Vec::with_capacity(events.len() / nprod + 1);
                    for (t, sample) in events.iter().skip(p).step_by(nprod)
                    {
                        let q0 = Instant::now();
                        h.submit(*t, sample.clone());
                        lats.push(q0.elapsed().as_nanos() as u64);
                    }
                    lats
                })
            })
            .collect();
        loop {
            let st = fe.pump(&mut router);
            windows += st.windows;
            // Keep the observed-window backlog drained like a real
            // off-line consumer so shard logs never hit their cap.
            router.take_observed();
            let done = producers.iter().all(|p| p.is_finished());
            if done && fe.resident() == 0 {
                break;
            }
            if st.drained == 0 {
                fe.wait_for_samples(Duration::from_millis(1));
            }
        }
        producers.into_iter().flat_map(|p| p.join().unwrap()).collect()
    });
    let wall_ns = t0.elapsed().as_nanos() as f64;
    let p1 = engine::pool_stats();

    // Zero-silent-loss reconciliation: cheap enough to run in every
    // mode, and the whole point of the explicit shed policy.
    for (t, st) in handle.stats() {
        assert_eq!(
            st.accepted + st.shed + st.resident,
            st.submitted,
            "{label}: tenant {t:?} leaked samples"
        );
        assert_eq!(
            st.resident, 0,
            "{label}: tenant {t:?} still resident after final drain"
        );
    }
    let totals = handle.totals();
    assert_eq!(
        totals.submitted,
        events.len() as u64,
        "{label}: submit count does not match the event stream"
    );
    assert_eq!(
        windows * wsize as u64 + fe.open_samples() as u64,
        totals.accepted,
        "{label}: accepted samples do not reconcile with windows built"
    );
    if policy == ShedPolicy::Block {
        assert_eq!(totals.shed, 0, "{label}: Block must never shed");
    }

    lat.sort_unstable();
    let p50 = lat[lat.len() / 2];
    let p99 = lat[(lat.len() * 99 / 100).min(lat.len() - 1)];
    StageOutcome {
        wall_ns,
        windows,
        submitted: totals.submitted,
        accepted: totals.accepted,
        shed: totals.shed,
        p50_ns: p50,
        p99_ns: p99,
        steals: p1.steals - p0.steals,
        parks: p1.parks - p0.parks,
        executed: p1.tasks_executed - p0.tasks_executed,
    }
}

fn main() {
    let smoke = matches!(std::env::var("KERMIT_SMOKE").as_deref(),
        Ok(v) if !v.is_empty() && v != "0");
    let (tenants, n_events) =
        if smoke { (200, 2_000) } else { (10_000, 60_000) };
    let (wsize, qcap) = (8usize, 64usize);
    let nprod = if smoke { 2 } else { 4 };
    let eng = Engine::auto();

    println!(
        "ingest stress: {tenants} tenants, {n_events} events \
         (zipf s=1.1, mean burst 4), window {wsize}, queue cap {qcap}, \
         {nprod} producers, {} engine threads{}",
        eng.threads(),
        if smoke { " [smoke]" } else { "" },
    );
    let events =
        heavy_tailed_stream(0xBEEF, tenants, n_events, 1.1, 4, &[0, 2, 5]);

    let mut t = Table::new(&[
        "stage",
        "wall",
        "windows/s",
        "p50 enqueue",
        "p99 enqueue",
        "submitted",
        "accepted",
        "shed",
        "steals",
        "parks",
    ]);
    let stages = [
        ("block", ShedPolicy::Block),
        ("shed_oldest", ShedPolicy::ShedOldest),
        ("shed_newest", ShedPolicy::ShedNewest),
    ];
    for (label, policy) in stages {
        let o = run_stage(label, policy, &events, wsize, qcap, nprod, eng);
        let rate = o.windows as f64 / (o.wall_ns / 1e9);
        t.metric(&format!("{label}_wall_ns"), o.wall_ns);
        t.metric(&format!("{label}_p50_enqueue_ns"), o.p50_ns as f64);
        t.metric(&format!("{label}_p99_enqueue_ns"), o.p99_ns as f64);
        t.row(&[
            label.into(),
            fmt_ns(o.wall_ns),
            format!("{rate:.0}"),
            fmt_ns(o.p50_ns as f64),
            fmt_ns(o.p99_ns as f64),
            o.submitted.to_string(),
            o.accepted.to_string(),
            o.shed.to_string(),
            o.steals.to_string(),
            format!("{} ({} tasks)", o.parks, o.executed),
        ]);
    }
    println!();
    t.print();

    // Smoke gate for CI: with a multi-threaded engine the executor must
    // demonstrably fan out. The stress stages almost always exercise it
    // already; if the caller happened to claim every chunk first, a
    // bounded nudge loop of wide dispatches gives workers time to win a
    // few claims before we assert.
    if smoke && eng.threads() > 1 {
        let eng1 = eng.with_min_items(1);
        let mut spins = 0;
        while engine::pool_stats().tasks_executed == 0 && spins < 500 {
            let mut items = vec![0u64; 64];
            eng1.for_rows(&mut items, 1, |_, chunk| {
                for v in chunk.iter_mut() {
                    let mut acc = 1u64;
                    for k in 0..2_000u64 {
                        acc = acc
                            .wrapping_mul(6364136223846793005)
                            .wrapping_add(k);
                    }
                    *v = acc;
                }
            });
            std::hint::black_box(&items);
            spins += 1;
        }
        let ps = engine::pool_stats();
        assert!(ps.workers >= 1, "executor never spawned a worker");
        assert!(
            ps.tasks_executed > 0,
            "fan-out never engaged: workers executed no tasks"
        );
    }

    let ps = engine::pool_stats();
    println!(
        "\npool: {} workers, {} jobs, {} tasks injected / {} executed \
         by workers / {} by callers, {} steals ({} tasks), {} parks, \
         spawn latency mean {} max {}",
        ps.workers,
        ps.jobs,
        ps.tasks_injected,
        ps.tasks_executed,
        ps.caller_chunks,
        ps.steals,
        ps.stolen_tasks,
        ps.parks,
        fmt_ns(ps.spawn_latency_mean_ns as f64),
        fmt_ns(ps.spawn_latency_max_ns as f64),
    );
    t.metric("pool_spawn_latency_mean_ns", ps.spawn_latency_mean_ns as f64);
    t.metric("pool_spawn_latency_max_ns", ps.spawn_latency_max_ns as f64);

    t.meta("engine_threads", &eng.threads().to_string());
    t.meta("engine_pool", "work-stealing");
    t.meta("simd_tier", engine::simd_tier());
    t.meta("smoke", if smoke { "1" } else { "0" });
    t.meta("tenants", &tenants.to_string());
    t.meta("events", &n_events.to_string());
    t.meta("window_size", &wsize.to_string());
    t.meta("queue_cap", &qcap.to_string());
    t.meta("producers", &nprod.to_string());

    let out = std::path::Path::new("BENCH_ingest.json");
    match t.write_json(out) {
        Ok(()) => println!("\nwrote {}", out.display()),
        Err(e) => println!("\nfailed to write {}: {e}", out.display()),
    }
}
