//! **End-to-end driver**: the full KERMIT MAPE-K loop on a realistic
//! compressed "business day" — recurring jobs, a new workload appearing
//! mid-day, and workload drift — against the default-config,
//! rule-of-thumb and oracle baselines.
//!
//! This is the repository's headline validation run: it exercises every
//! layer (monitoring, change detection, discovery, ZSL, classification,
//! prediction, Algorithm 1, Explorer search sessions, the WorkloadDB)
//! and reports the paper's metrics. Results are recorded in
//! EXPERIMENTS.md.
//!
//! Run: `cargo run --release --example autonomic_loop`

use kermit::benchkit::{pct, Table};
use kermit::coordinator::{
    run_fixed_config, run_oracle, Coordinator, CoordinatorConfig,
};
use kermit::explorer::baselines::rule_of_thumb;
use kermit::online::ChoiceKind;
use kermit::simcluster::{default_config_index, JobSpec};
use kermit::workloadgen::Mix;

fn main() {
    // ---- the day's schedule ------------------------------------------------
    // morning: recurring rotation of 3 job types
    // midday:  a new job type (class 7) joins the rotation
    // afternoon: a multi-user hybrid burst (classes 0+5 sharing the cluster)
    let mut jobs = Vec::new();
    for _ in 0..30 {
        for c in [0u32, 3, 5] {
            jobs.push(JobSpec { mix: Mix::Pure(c) });
        }
    }
    for _ in 0..20 {
        for c in [0u32, 3, 5, 7] {
            jobs.push(JobSpec { mix: Mix::Pure(c) });
        }
    }
    for _ in 0..30 {
        jobs.push(JobSpec { mix: Mix::Hybrid(0, 5, 0.5) });
        jobs.push(JobSpec { mix: Mix::Pure(3) });
        jobs.push(JobSpec { mix: Mix::Pure(7) });
    }
    println!("schedule: {} jobs (recurring + new type + hybrid burst)", jobs.len());

    // ---- run all four policies ---------------------------------------------
    let mut cfg = CoordinatorConfig::default();
    cfg.offline_interval_windows = 12;
    cfg.engine.duration_noise = 0.02;
    let mut coord = Coordinator::new(cfg.clone());
    // on-line operating point: ~22 probes reaches ~93% tuning efficiency
    // (see the budget ablation in EXPERIMENTS.md) while converging within
    // a morning's recurrences — the paper's low-overhead regime
    coord.plugin.explorer_config.global_budget = 22;
    coord.plugin.explorer_config.local_budget = 10;

    let t0 = std::time::Instant::now();
    let kermit = coord.run_schedule(&jobs);
    let wall = t0.elapsed();
    let default =
        run_fixed_config(&jobs, default_config_index(), &cfg.engine, 7);
    let rot = run_fixed_config(&jobs, rule_of_thumb(), &cfg.engine, 7);
    let oracle = run_oracle(&jobs, &cfg.engine, 7);

    let mut t = Table::new(&[
        "policy", "makespan(s)", "mean job(s)", "steady(s, last 30)",
        "vs default",
    ]);
    for (name, r) in [
        ("kermit", &kermit),
        ("default", &default),
        ("rule-of-thumb", &rot),
        ("oracle", &oracle),
    ] {
        t.row(&[
            name.to_string(),
            format!("{:.0}", r.makespan),
            format!("{:.1}", r.mean_duration()),
            format!("{:.1}", r.tail_mean_duration(30)),
            pct(1.0 - r.makespan / default.makespan),
        ]);
    }
    t.print();

    // ---- autonomic behaviour narrative -------------------------------------
    println!("\n-- learning curve (mean duration per 30-job phase) --");
    let phase = |a: usize, b: usize| -> f64 {
        let s: f64 =
            kermit.jobs[a..b.min(kermit.jobs.len())].iter().map(|j| j.duration).sum();
        s / (b.min(kermit.jobs.len()) - a) as f64
    };
    let n = kermit.jobs.len();
    for k in (0..n).step_by(30) {
        let hi = (k + 30).min(n);
        println!("  jobs {k:>3}-{hi:>3}: {:>8.1}s", phase(k, hi));
    }

    println!("\n-- plug-in decisions --");
    let count = |k: ChoiceKind| {
        kermit.jobs.iter().filter(|j| j.choice == k).count()
    };
    println!("  default        : {}", count(ChoiceKind::Default));
    println!("  global probes  : {}", count(ChoiceKind::GlobalProbe));
    println!("  local probes   : {}", count(ChoiceKind::LocalProbe));
    println!("  cache hits     : {}", count(ChoiceKind::CacheHit));
    println!("  searches done  : {}", kermit.plugin_stats.searches_completed);

    println!("\n-- knowledge --");
    println!("  workload types known : {}", kermit.workloads_known);
    println!(
        "  label consistency    : {}",
        pct(kermit.classification_consistency())
    );
    println!(
        "  steady-state efficiency vs oracle: {}",
        pct(oracle.tail_mean_duration(30) / kermit.tail_mean_duration(30))
    );
    println!(
        "  steady-state gain vs rule-of-thumb: {}",
        pct(1.0 - kermit.tail_mean_duration(30) / rot.tail_mean_duration(30))
    );
    println!("\nsimulation wall-clock: {wall:.2?}");
}
