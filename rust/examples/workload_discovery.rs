//! Off-line sub-system walkthrough: Algorithm 2 over successive batches
//! — new workload discovery, re-matching on recurrence, and drift
//! detection — with the WorkloadDB persisted between batches like a
//! real deployment restart.
//!
//! Run: `cargo run --release --example workload_discovery`

use kermit::clustering::NativeDistance;
use kermit::features::NUM_FEATURES;
use kermit::knowledge::{KnowledgeZones, WorkloadDb};
use kermit::monitor::{aggregate_trace, MonitorConfig};
use kermit::offline::{discover, DiscoveryConfig};
use kermit::workloadgen::{
    tour_schedule, GenConfig, Generator, Mix, ScheduleEntry,
};

fn main() -> kermit::util::error::Result<()> {
    let zones_dir = std::env::temp_dir().join("kermit_discovery_demo");
    std::fs::remove_dir_all(&zones_dir).ok();
    let zones = KnowledgeZones::create(&zones_dir)?;
    let mcfg = MonitorConfig { window_size: 30 };
    let dcfg = DiscoveryConfig::default();

    // ---- batch 1: three job types, never seen before
    println!("== batch 1: first sight of classes 0, 2, 5 ==");
    let mut g = Generator::with_default_config(10);
    let t1 = g.generate(&tour_schedule(400, &[0, 2, 5]));
    let w1 = aggregate_trace(&t1, &mcfg);
    zones.append_windows(&w1)?;
    let mut db = WorkloadDb::new();
    let r1 = discover(&w1, &mut db, &dcfg, &NativeDistance);
    for o in &r1.outcomes {
        println!("  {o:?}");
    }
    db.save(&zones.workload_db_path())?;
    println!("  -> DB saved with {} workloads\n", db.len());

    // ---- batch 2 (after restart): same classes recur + one new class
    println!("== batch 2: recurrence of 0, 2 + new class 7 (after restart) ==");
    let mut db = WorkloadDb::load(&zones.workload_db_path())?;
    let t2 = g.generate(&tour_schedule(400, &[0, 7, 2]));
    let w2 = aggregate_trace(&t2, &mcfg);
    zones.append_windows(&w2)?;
    let r2 = discover(&w2, &mut db, &dcfg, &NativeDistance);
    for o in &r2.outcomes {
        println!("  {o:?}");
    }
    println!("  -> DB now has {} workloads\n", db.len());

    // ---- batch 3: class 0 drifts (systematic mean shift)
    println!("== batch 3: class 0 drifts (systematic shift) ==");
    let mut cfg = GenConfig::default();
    let mut rate = [0.0; NUM_FEATURES];
    rate[0] = 0.05; // cpu_user climbing
    rate[3] = 0.04; // memory climbing
    cfg.drift_per_sample = vec![(0, rate)];
    let mut gd = Generator::new(11, cfg);
    let td = gd.generate(&[ScheduleEntry {
        mix: Mix::Pure(0),
        duration: 600,
    }]);
    // analyse only the drifted tail
    let tail: Vec<_> = td.samples[300..].to_vec();
    let wd = kermit::monitor::aggregate_samples(&tail, &mcfg);
    let r3 = discover(&wd, &mut db, &dcfg, &NativeDistance);
    for o in &r3.outcomes {
        println!("  {o:?}");
    }
    for label in r3.drifted_labels() {
        let e = db.get(label).unwrap();
        println!(
            "  label {label}: is_drifting={} optimal_config_found={}",
            e.is_drifting, e.optimal_config_found
        );
    }
    db.save(&zones.workload_db_path())?;

    println!("\nfinal WorkloadDB ({} entries):", db.len());
    for e in db.entries() {
        println!(
            "  label {:>2}  windows {:>4}  drifting {:>5}  synthetic {}",
            e.label, e.window_count, e.is_drifting, e.synthetic
        );
    }
    println!("\nknowledge zones on disk: {}", zones_dir.display());
    Ok(())
}
