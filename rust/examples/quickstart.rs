//! Quickstart: the smallest end-to-end taste of KERMIT.
//!
//! Generates a short workload trace, discovers the workload types
//! off-line (Algorithm 2), trains the WorkloadClassifier, classifies a
//! held-out trace in real time, and tunes one workload with the
//! Explorer.
//!
//! Run: `cargo run --release --example quickstart`

use kermit::clustering::NativeDistance;
use kermit::explorer::baselines::exhaustive;
use kermit::explorer::Explorer;
use kermit::knowledge::WorkloadDb;
use kermit::ml::Classifier;
use kermit::monitor::{aggregate_trace, MonitorConfig};
use kermit::offline::{discover, train, DiscoveryConfig, TrainingConfig};
use kermit::simcluster::config_space::ConfigIndex;
use kermit::simcluster::perfmodel::job_duration;
use kermit::util::rng::Rng;
use kermit::workloadgen::{tour_schedule, Generator};

fn main() {
    // 1. a day's worth of metrics from three workload types
    println!("1) generating workload trace (3 classes)...");
    let mut g = Generator::with_default_config(1);
    let trace = g.generate(&tour_schedule(400, &[0, 2, 5]));
    let windows =
        aggregate_trace(&trace, &MonitorConfig { window_size: 30 });
    println!("   {} samples -> {} observation windows", trace.len(), windows.len());

    // 2. off-line discovery (Algorithm 2): no labels needed
    println!("2) discovering workload types (DBSCAN)...");
    let mut db = WorkloadDb::new();
    let report = discover(
        &windows,
        &mut db,
        &DiscoveryConfig::default(),
        &NativeDistance,
    );
    println!("   discovered {} workload types:", db.len());
    for o in &report.outcomes {
        println!("     {o:?}");
    }

    // 3. automated training (no human labelling anywhere)
    println!("3) training the WorkloadClassifier (random forest + ZSL)...");
    let mut rng = Rng::new(2);
    let models = train(
        &windows,
        &report,
        &mut db,
        &TrainingConfig::default(),
        &mut rng,
    );
    println!(
        "   training set: {} windows ({} incl. synthetic hybrids)",
        report.window_labels.iter().flatten().count(),
        models.workload_set_size
    );

    // 4. real-time classification of a fresh trace
    println!("4) classifying a held-out trace...");
    let mut g2 = Generator::with_default_config(99);
    let t2 = g2.generate(&tour_schedule(150, &[0, 2, 5]));
    let w2 = aggregate_trace(&t2, &MonitorConfig { window_size: 30 });
    let hits = w2
        .iter()
        .filter(|w| w.truth.is_some())
        .map(|w| {
            let aw = kermit::features::AnalyticWindow::from_observation(w);
            models.workload_forest.predict(&aw.features)
        })
        .count();
    println!("   classified {hits} steady windows in real time");

    // 5. tune one workload with the Explorer
    println!("5) tuning workload class 2 (terasort-like)...");
    let mut eval = |c: ConfigIndex| job_duration(2, &c.to_config());
    let found = Explorer::with_defaults().global_search(&mut eval);
    let oracle = exhaustive(&mut eval);
    println!(
        "   explorer: {:.1}s in {} probes | exhaustive best: {:.1}s in {} probes",
        found.best_duration, found.probes, oracle.best_duration, oracle.probes
    );
    println!(
        "   tuning efficiency: {:.1}%",
        100.0 * oracle.best_duration / found.best_duration
    );
    println!("\ndone — see examples/autonomic_loop.rs for the full MAPE-K loop");
}
