//! All-artifact pipeline: every ML stage running through the AOT XLA
//! artifacts (the L1 pallas kernels), none through native rust math —
//! the configuration a TPU deployment would use.
//!
//!   1. batch window aggregation     -> `welch_stats` artifact
//!   2. DBSCAN distance matrix       -> `pairwise_dist` artifact
//!   3. workload classification     -> `mlp_fwd`/`mlp_train` artifacts
//!   4. workload prediction         -> `lstm_fwd`/`lstm_train` artifacts
//!
//! Run: `cargo run --release --example nn_pipeline` (needs `make artifacts`)

use kermit::benchkit::pct;
use kermit::clustering::{dbscan, DbscanConfig};
use kermit::features::AnalyticWindow;
use kermit::knowledge::{Characterization, WorkloadDb};
use kermit::linalg::Matrix;
use kermit::ml::Dataset;
use kermit::online::classifier::WindowClassifier;
use kermit::online::predictor::sequence_accuracy;
use kermit::runtime::nn::{
    ArtifactDistance, LstmPredictor, MlpClassifier, WelchAggregator,
};
use kermit::runtime::Runtime;
use kermit::workloadgen::{tour_schedule, Generator};

fn main() -> kermit::util::error::Result<()> {
    let rt = Runtime::load(&Runtime::default_dir())?;
    println!("artifacts loaded: {:?}\n", rt.names());

    // ---- 1. welch_stats aggregation ----------------------------------
    let mut g = Generator::with_default_config(21);
    // 12 repetitions of the 4-class rotation: enough plateau labels to
    // train the LSTM predictor on the recurrence
    let rotation: Vec<u32> = (0..12).flat_map(|_| [0u32, 2, 5, 7]).collect();
    let trace = g.generate(&tour_schedule(200, &rotation));
    let agg = WelchAggregator::new(&rt)?;
    let windows = agg.aggregate(&trace.samples, 0)?;
    println!(
        "1) welch_stats artifact: {} samples -> {} windows",
        trace.len(),
        windows.len()
    );

    // ---- 2. pairwise_dist DBSCAN discovery ---------------------------
    let rows = Matrix::from_rows(
        &windows
            .iter()
            .filter(|w| w.truth.is_some())
            .map(|w| AnalyticWindow::from_observation(w).features)
            .collect::<Vec<Vec<f64>>>(),
    );
    let truths: Vec<u32> = windows
        .iter()
        .filter_map(|w| w.truth)
        .collect();
    let ad = ArtifactDistance::new(&rt)?;
    let clusters =
        dbscan(&rows, &DbscanConfig { eps: 10.0, min_pts: 4 }, &ad);
    println!(
        "2) pairwise_dist artifact DBSCAN: {} clusters (4 true classes), purity {}",
        clusters.n_clusters,
        pct(kermit::clustering::purity(&truths, &clusters.labels)),
    );

    // register in a DB (labels = cluster ids via characterization)
    let mut db = WorkloadDb::new();
    let mut train = Dataset::new();
    for c in 0..clusters.n_clusters as i32 {
        let members = clusters.members(c);
        let member_rows = rows.gather(&members);
        let ch = Characterization::from_rows(&member_rows);
        let cen = ch.mean_vector();
        let label = db.insert_new(ch, cen, members.len(), false);
        for r in member_rows.iter_rows() {
            train.push(r, label);
        }
    }

    // ---- 3. MLP classification ----------------------------------------
    let mlp = MlpClassifier::new(&rt, 0)?;
    let loss = mlp.fit(&train, 40, 0.05, 1)?;
    // held-out windows from a fresh trace
    let mut g2 = Generator::with_default_config(99);
    let rot2: Vec<u32> = (0..4).flat_map(|_| [0u32, 2, 5, 7]).collect();
    let t2 = g2.generate(&tour_schedule(200, &rot2));
    let w2 = agg.aggregate(&t2.samples, 0)?;
    let mut hits = 0usize;
    let mut total = 0usize;
    let mut label_seq: Vec<u32> = Vec::new();
    let mut truth_of_label: std::collections::BTreeMap<u32, u32> =
        Default::default();
    for w in w2.iter().filter(|w| w.truth.is_some()) {
        let aw = AnalyticWindow::from_observation(w);
        let pred = mlp.classify(&aw.features);
        if pred != kermit::online::UNKNOWN {
            total += 1;
            let entry = truth_of_label.entry(pred).or_insert(w.truth.unwrap());
            if *entry == w.truth.unwrap() {
                hits += 1;
            }
            if label_seq.last() != Some(&pred) {
                label_seq.push(pred);
            }
        }
    }
    println!(
        "3) mlp artifact classifier: train loss {loss:.3}, held-out consistency {} ({total} windows)",
        pct(hits as f64 / total.max(1) as f64)
    );

    // ---- 4. LSTM prediction -------------------------------------------
    let lstm = LstmPredictor::new(&rt, 0)?;
    // train on a long recurring label sequence (the tour repeats)
    let mut full_seq: Vec<u32> = Vec::new();
    for w in windows.iter().filter(|w| w.truth.is_some()) {
        let aw = AnalyticWindow::from_observation(w);
        let l = mlp.classify(&aw.features);
        if l != kermit::online::UNKNOWN && full_seq.last() != Some(&l) {
            full_seq.push(l);
        }
    }
    let lstm_loss = lstm.train_on_sequence(&full_seq, 30, 0.4, 2)?;
    let acc = sequence_accuracy(&lstm, &label_seq, 1, 2);
    println!(
        "4) lstm artifact predictor: train loss {lstm_loss:.3}, t+1 accuracy {} on held-out label sequence",
        pct(acc)
    );
    println!("\nall four artifact paths exercised — python never ran.");
    Ok(())
}
