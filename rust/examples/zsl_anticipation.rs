//! Zero-shot anticipation demo ([9], paper §7.2 step 7): KERMIT names a
//! multi-user hybrid workload the *first time it ever appears*, because
//! the WorkloadSynthesizer anticipated it from the pure classes.
//!
//! Run: `cargo run --release --example zsl_anticipation`

use kermit::benchkit::pct;
use kermit::experiments::zsl;

fn main() {
    println!("== Zero-shot anticipation of unseen hybrid workloads ==\n");
    println!("protocol:");
    println!("  1. train only on PURE workload classes (0, 2, 3, 5)");
    println!("  2. WorkloadSynthesizer blends pure characterizations into");
    println!("     anticipated hybrid prototypes + synthetic instances");
    println!("  3. test on REAL two-tenant hybrid traces never observed\n");

    for seed in [3u64, 7, 13] {
        let r = zsl::run(seed);
        println!(
            "seed {seed}: {} hybrid test windows | zsl accuracy {} | \
             without synthesizer {} | pure accuracy {}",
            r.n_hybrid_tests,
            pct(r.zsl_accuracy),
            pct(r.ablation_accuracy),
            pct(r.pure_accuracy),
        );
    }
    println!("\npaper claim ([9]): classify unseen hybrids with up to 83%");
    println!("note the ablation: without synthesis the hybrid label does not");
    println!("exist in the training set, so naming it is impossible (0%).");
}
