//! `Engine::auto()`'s `KERMIT_THREADS` override, in its own
//! integration-test binary (own process): `std::env::set_var` racing a
//! concurrent `getenv` from another thread is undefined behavior on
//! glibc, so the single test below must be the only code in this
//! process touching the environment while it runs. Do not add other
//! tests to this file.

use kermit::linalg::engine::Engine;

#[test]
fn auto_honors_kermit_threads_env() {
    let host =
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    // start from a clean slate: the developer's shell (or a job-wide CI
    // export) may legitimately have the knob set
    std::env::remove_var("KERMIT_THREADS");
    assert_eq!(Engine::auto().threads(), host, "no override set");
    std::env::set_var("KERMIT_THREADS", "3");
    assert_eq!(Engine::auto().threads(), 3);
    std::env::set_var("KERMIT_THREADS", "0");
    assert_eq!(Engine::auto().threads(), 1, "clamped to >= 1");
    std::env::set_var("KERMIT_THREADS", "not-a-number");
    assert_eq!(Engine::auto().threads(), host, "unparsable falls back");
    std::env::remove_var("KERMIT_THREADS");
    assert_eq!(Engine::auto().threads(), host, "unset falls back");
}
