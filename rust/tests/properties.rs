//! Property-based tests (own `testkit` harness) on the coordinator-
//! facing invariants: RM accounting, window aggregation, WorkloadDB,
//! Explorer budgets/validity, DBSCAN label validity, JSON round-trips,
//! metric bounds.

use kermit::clustering::{dbscan, DbscanConfig, NativeDistance, NOISE};
use kermit::explorer::{ConfigEvaluator, Explorer, ExplorerConfig};
use kermit::features::ObservationWindow;
use kermit::knowledge::{Characterization, WorkloadDb};
use kermit::linalg::Matrix;
use kermit::simcluster::config_space::ConfigIndex;
use kermit::simcluster::{NodeSpec, ResourceManager};
use kermit::testkit::{forall, gen};
use kermit::util::json::Json;
use kermit::util::rng::Rng;

#[test]
fn prop_rm_accounting_never_oversubscribes() {
    forall(
        1,
        60,
        |rng| {
            // a random sequence of alloc/release ops
            let ops: Vec<(bool, u32, u32)> = (0..80)
                .map(|_| {
                    (
                        rng.chance(0.6),
                        rng.range_usize(1, 9) as u32,
                        rng.range_usize(256, 8193) as u32,
                    )
                })
                .collect();
            ops
        },
        |ops| {
            let mut rm = ResourceManager::new(vec![
                NodeSpec { cores: 8, mem_mb: 16384 },
                NodeSpec { cores: 16, mem_mb: 8192 },
            ]);
            let mut live: Vec<u64> = Vec::new();
            for &(alloc, cores, mem) in ops {
                if alloc {
                    if let Ok(c) = rm.allocate(cores, mem) {
                        live.push(c.id);
                    }
                } else if !live.is_empty() {
                    let id = live.remove(live.len() / 2);
                    rm.release(id).map_err(|e| e.to_string())?;
                }
                rm.check_invariants(); // panics on violation
            }
            Ok(())
        },
    );
}

#[test]
fn prop_window_aggregation_mean_within_sample_range() {
    forall(
        2,
        60,
        |rng| {
            let n = rng.range_usize(2, 50);
            gen::rows(rng, n, kermit::features::NUM_FEATURES, -50.0, 50.0)
        },
        |rows| {
            let samples: Vec<kermit::features::FeatureVec> = rows
                .iter()
                .map(|r| {
                    let mut f = [0.0; kermit::features::NUM_FEATURES];
                    f.copy_from_slice(r);
                    f
                })
                .collect();
            let w = ObservationWindow::aggregate(0, 0.0, &samples, None);
            for i in 0..kermit::features::NUM_FEATURES {
                let lo = samples.iter().map(|s| s[i]).fold(f64::MAX, f64::min);
                let hi = samples.iter().map(|s| s[i]).fold(f64::MIN, f64::max);
                if w.mean[i] < lo - 1e-9 || w.mean[i] > hi + 1e-9 {
                    return Err(format!("mean[{i}] outside sample range"));
                }
                if w.var[i] < 0.0 {
                    return Err(format!("negative variance[{i}]"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_workload_db_labels_unique_and_persistent() {
    forall(
        3,
        40,
        |rng| {
            let n = rng.range_usize(1, 12);
            (0..n)
                .map(|_| gen::rows(rng, 3, 6, 0.0, 100.0))
                .collect::<Vec<_>>()
        },
        |clusters| {
            let mut db = WorkloadDb::new();
            let mut labels = Vec::new();
            for rows in clusters {
                let ch = Characterization::from_vec_rows(rows);
                let cen = ch.mean_vector();
                labels.push(db.insert_new(ch, cen, rows.len(), false));
            }
            // unique + monotone
            for pair in labels.windows(2) {
                if pair[1] <= pair[0] {
                    return Err("labels not monotone".into());
                }
            }
            // json round-trip preserves everything relevant
            let back = WorkloadDb::from_json(&db.to_json())
                .map_err(|e| e.to_string())?;
            if back.len() != db.len() {
                return Err("roundtrip lost entries".into());
            }
            for l in &labels {
                let (a, b) = (db.get(*l).unwrap(), back.get(*l).unwrap());
                if a.centroid != b.centroid {
                    return Err(format!("centroid mismatch for {l}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_explorer_respects_budget_and_returns_measured_config() {
    forall(
        4,
        25,
        |rng| {
            // random smooth-ish surface: weighted distance from a random
            // grid point + a second basin
            let dims = ConfigIndex::dims();
            let target: Vec<usize> =
                dims.iter().map(|&d| rng.range_usize(0, d)).collect();
            let weights = gen::vec_f64(rng, 6, 0.5, 4.0);
            let budget = rng.range_usize(5, 80);
            (target, weights, budget)
        },
        |(target, weights, budget)| {
            struct Counting<'a> {
                target: &'a [usize],
                weights: &'a [f64],
                calls: usize,
                probed: std::collections::HashMap<ConfigIndex, f64>,
            }
            impl ConfigEvaluator for Counting<'_> {
                fn measure(&mut self, c: ConfigIndex) -> f64 {
                    self.calls += 1;
                    let d: f64 = c
                        .0
                        .iter()
                        .zip(self.target)
                        .zip(self.weights)
                        .map(|((&a, &t), &w)| {
                            w * (a as f64 - t as f64).powi(2)
                        })
                        .sum::<f64>()
                        + 1.0;
                    self.probed.insert(c, d);
                    d
                }
            }
            let mut eval = Counting {
                target,
                weights,
                calls: 0,
                probed: Default::default(),
            };
            let ex = Explorer::new(ExplorerConfig {
                global_budget: *budget,
                local_budget: 8,
                min_improvement: 0.0,
            });
            let r = ex.global_search(&mut eval);
            if eval.calls > *budget {
                return Err(format!(
                    "{} probes > budget {budget}",
                    eval.calls
                ));
            }
            if r.probes != eval.calls {
                return Err("probe count mismatch".into());
            }
            // the returned best must be a config that was actually
            // measured, with its measured value
            match eval.probed.get(&r.best) {
                Some(&v) if (v - r.best_duration).abs() < 1e-9 => Ok(()),
                Some(_) => Err("best_duration != measured value".into()),
                None => Err("returned config never measured".into()),
            }
        },
    );
}

#[test]
fn prop_dbscan_labels_valid_and_deterministic() {
    forall(
        5,
        30,
        |rng| {
            let n = rng.range_usize(5, 120);
            let w = rng.range_usize(2, 8);
            (
                gen::rows(rng, n, w, -20.0, 20.0),
                rng.range_f64(0.5, 15.0),
                rng.range_usize(2, 6),
            )
        },
        |(rows, eps, min_pts)| {
            let cfg = DbscanConfig { eps: *eps, min_pts: *min_pts };
            let m = Matrix::from_rows(rows);
            let a = dbscan(&m, &cfg, &NativeDistance);
            let b = dbscan(&m, &cfg, &NativeDistance);
            if a.labels != b.labels {
                return Err("nondeterministic".into());
            }
            // labels are NOISE or within [0, n_clusters)
            for &l in &a.labels {
                if l != NOISE && !(0..a.n_clusters as i32).contains(&l) {
                    return Err(format!("invalid label {l}"));
                }
            }
            // every cluster id in range is used
            for c in 0..a.n_clusters as i32 {
                if !a.labels.contains(&c) {
                    return Err(format!("cluster {c} empty"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_json_roundtrip_arbitrary_values() {
    fn arb_json(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.chance(0.5)),
            2 => Json::Num((rng.range_f64(-1e6, 1e6) * 100.0).round() / 100.0),
            3 => {
                let n = rng.range_usize(0, 8);
                Json::Str(
                    (0..n)
                        .map(|_| {
                            *rng.choice(&[
                                'a', 'é', '"', '\\', '\n', '😀', ' ', 'z',
                            ])
                        })
                        .collect(),
                )
            }
            4 => Json::Arr(
                (0..rng.range_usize(0, 4))
                    .map(|_| arb_json(rng, depth - 1))
                    .collect(),
            ),
            _ => {
                let mut o = Json::obj();
                for i in 0..rng.range_usize(0, 4) {
                    o.set(&format!("k{i}"), arb_json(rng, depth - 1));
                }
                o
            }
        }
    }
    forall(
        6,
        200,
        |rng| arb_json(rng, 3),
        |j| {
            let enc = j.encode();
            let back = Json::parse(&enc).map_err(|e| e.to_string())?;
            if &back != j {
                return Err(format!("roundtrip mismatch: {enc}"));
            }
            // pretty round-trips too
            let back2 = Json::parse(&j.encode_pretty())
                .map_err(|e| e.to_string())?;
            if &back2 != j {
                return Err("pretty roundtrip mismatch".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_clustering_metrics_bounded() {
    forall(
        7,
        100,
        |rng| {
            let n = rng.range_usize(1, 60);
            (
                gen::labels(rng, n, 5),
                (0..n)
                    .map(|_| rng.below(6) as i32 - 1) // -1..4 incl. noise
                    .collect::<Vec<i32>>(),
            )
        },
        |(truth, cluster)| {
            let p = kermit::clustering::purity(truth, cluster);
            let a = kermit::clustering::awt(truth, cluster);
            if !(0.0..=1.0).contains(&p) {
                return Err(format!("purity {p} out of bounds"));
            }
            if !(0.0..=1.0).contains(&a) {
                return Err(format!("awt {a} out of bounds"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_matrix_from_rows_roundtrips_iter_rows() {
    forall(
        9,
        120,
        |rng| {
            let n = rng.range_usize(0, 40);
            let w = rng.range_usize(1, 12);
            gen::rows(rng, n, w, -1e4, 1e4)
        },
        |rows| {
            let m = Matrix::from_rows(rows);
            if m.n_rows() != rows.len() {
                return Err(format!(
                    "row count {} != {}",
                    m.n_rows(),
                    rows.len()
                ));
            }
            if !rows.is_empty() && m.n_cols() != rows[0].len() {
                return Err("width mismatch".into());
            }
            // iter_rows round-trips every row bit-exactly, in order
            for (i, (got, want)) in m.iter_rows().zip(rows).enumerate() {
                if got != want.as_slice() {
                    return Err(format!("row {i} mismatch"));
                }
            }
            // indexed access agrees with iteration
            for i in 0..m.n_rows() {
                if m.row(i) != rows[i].as_slice() {
                    return Err(format!("row({i}) mismatch"));
                }
            }
            // flat storage is the concatenation of the rows
            let flat: Vec<f64> =
                rows.iter().flat_map(|r| r.iter().copied()).collect();
            if m.as_slice() != flat.as_slice() {
                return Err("flat storage mismatch".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_sq_dist_matches_naive() {
    forall(
        10,
        150,
        |rng| {
            let w = rng.range_usize(1, 40);
            (
                gen::vec_f64(rng, w, -100.0, 100.0),
                gen::vec_f64(rng, w, -100.0, 100.0),
            )
        },
        |(a, b)| {
            let naive: f64 =
                a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum();
            let got = kermit::linalg::sq_dist(a, b);
            let tol = 1e-9 * naive.max(1.0);
            if (got - naive).abs() > tol {
                return Err(format!("{got} vs {naive}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_summary_percentiles_ordered() {
    forall(
        8,
        100,
        |rng| {
            let n = rng.range_usize(1, 200);
            gen::vec_f64(rng, n, -1e3, 1e3)
        },
        |xs| {
            let s = kermit::stats::Summary::of(xs);
            if !(s.min <= s.p75 && s.p75 <= s.p90 && s.p90 <= s.max) {
                return Err(format!("percentiles out of order: {s:?}"));
            }
            if s.mean < s.min - 1e-9 || s.mean > s.max + 1e-9 {
                return Err("mean outside [min,max]".into());
            }
            if s.std < 0.0 {
                return Err("negative std".into());
            }
            Ok(())
        },
    );
}
