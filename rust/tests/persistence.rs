//! Durable knowledge plane: cross-version compatibility and
//! byte-stability contracts for the snapshot path.
//!
//! Every WorkloadDB JSON shape this repo has ever written must keep
//! loading through the versioned codec path:
//!
//! * pre-chaos-lab rows (no `quarantined` / `best_duration` keys) —
//!   written by `WorkloadDb::save` before the poisoning detector
//!   existed;
//! * chaos-lab-era rows (quarantine fields present) — still bare
//!   magic-less JSON, before the envelope;
//! * current enveloped snapshots (magic + version + checksum).
//!
//! And the snapshot cycle must be a fixpoint: snapshot → recover →
//! snapshot yields byte-identical files, so repeated clean restarts
//! never churn the on-disk state.

use kermit::knowledge::persist::{
    read_snapshot, BinaryCodec, JsonCodec, KnowledgeStore, WalRecord,
    SNAPSHOT_VERSION,
};
use kermit::knowledge::workload_db::entry_to_json;
use kermit::knowledge::{Characterization, WorkloadDb};
use kermit::simcluster::config_space::ConfigIndex;
use kermit::util::json::Json;
use std::path::PathBuf;

fn temp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("kermit_persist_it_{name}"));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn sample_db() -> WorkloadDb {
    let mut db = WorkloadDb::new();
    let rows: Vec<Vec<f64>> = vec![vec![1.0, 2.0, 3.0], vec![2.0, 3.0, 4.0]];
    let a = db.insert_new(
        Characterization::from_vec_rows(&rows),
        vec![1.5, 2.5, 3.5],
        2,
        false,
    );
    let rows2: Vec<Vec<f64>> = vec![vec![9.0, 8.0, 7.0], vec![8.0, 7.0, 6.0]];
    let b = db.insert_new(
        Characterization::from_vec_rows(&rows2),
        vec![8.5, 7.5, 6.5],
        2,
        false,
    );
    db.set_optimal_measured(a, ConfigIndex([1, 2, 0, 1, 0, 2]), 41.5);
    db.set_optimal_config(b, ConfigIndex([0, 1, 1, 0, 2, 1]));
    db.quarantine(b);
    db
}

/// A pre-chaos-lab `WorkloadDb::save` file: bare JSON, no envelope,
/// and no `quarantined` / `best_duration` keys on any row.
fn legacy_pre_quarantine_json(db: &WorkloadDb) -> String {
    let workloads: Vec<Json> = db
        .entries()
        .map(|e| {
            let mut row = entry_to_json(e);
            let map = match &mut row {
                Json::Obj(m) => m,
                _ => unreachable!("entry rows are objects"),
            };
            map.remove("quarantined");
            map.remove("best_duration");
            row
        })
        .collect();
    let mut root = Json::obj();
    root.set("next_label", Json::Num(db.entries().count() as f64))
        .set("workloads", Json::Arr(workloads));
    root.encode_pretty()
}

#[test]
fn pre_quarantine_era_json_loads_through_the_codec_path() {
    let dir = temp_dir("legacy_v0");
    let db = sample_db();
    let path = dir.join("peer.kdb");
    std::fs::write(&path, legacy_pre_quarantine_json(&db)).unwrap();

    let payload = read_snapshot(&path).unwrap();
    assert_eq!(payload.version, 0, "magic-less files are version 0");
    assert_eq!(payload.last_seq, 0);
    let loaded = KnowledgeStore::import(&path).unwrap();
    assert_eq!(loaded.entries().count(), 2);
    for e in loaded.entries() {
        // absent fields default to trusted / unmeasured
        assert!(!e.quarantined);
        assert_eq!(e.best_duration, None);
    }
    let a = loaded.get(0).unwrap();
    assert!(a.optimal_config_found);
    assert_eq!(a.config, Some(ConfigIndex([1, 2, 0, 1, 0, 2])));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn quarantine_era_bare_json_loads_with_fields_intact() {
    let dir = temp_dir("legacy_v0_quarantine");
    let db = sample_db();
    let path = dir.join("peer.kdb");
    // chaos-lab era: full current row schema, still bare magic-less JSON
    std::fs::write(&path, db.to_json().encode_pretty()).unwrap();

    let loaded = KnowledgeStore::import(&path).unwrap();
    assert_eq!(loaded.entries().count(), 2);
    let b = loaded.get(1).unwrap();
    assert!(b.quarantined, "quarantine flag must survive the load");
    let a = loaded.get(0).unwrap();
    assert_eq!(a.best_duration, Some(41.5));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn export_files_carry_the_current_envelope_version() {
    let dir = temp_dir("export_version");
    let db = sample_db();
    for codec in [
        Box::new(JsonCodec) as Box<dyn kermit::knowledge::SnapshotCodec>,
        Box::new(BinaryCodec),
    ] {
        let path = dir.join(format!("export_{}.kdb", codec.name()));
        KnowledgeStore::export(&db, &path, codec.as_ref()).unwrap();
        let payload = read_snapshot(&path).unwrap();
        assert_eq!(payload.version, SNAPSHOT_VERSION);
        let loaded = KnowledgeStore::import(&path).unwrap();
        assert_eq!(
            loaded.to_json().encode(),
            db.to_json().encode(),
            "export/import must be lossless for {}",
            codec.name()
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn snapshot_recover_snapshot_is_byte_stable() {
    for (name, codec) in [
        ("json", Box::new(JsonCodec) as Box<dyn kermit::knowledge::SnapshotCodec>),
        ("binary", Box::new(BinaryCodec)),
    ] {
        let dir = temp_dir(&format!("byte_stable_{name}"));
        let reopen_codec: Box<dyn kermit::knowledge::SnapshotCodec> =
            if name == "json" {
                Box::new(JsonCodec)
            } else {
                Box::new(BinaryCodec)
            };

        // generation 1: a DB built through the journaled mutation path
        let (mut store, mut db, _) =
            KnowledgeStore::open(&dir, codec).unwrap();
        let seeded = sample_db();
        for e in seeded.entries() {
            db.restore_entry(e.clone());
            store
                .append(&WalRecord::Insert(Box::new(e.clone())))
                .unwrap();
        }
        let gen1 = store.snapshot(&db).unwrap();
        let bytes1 =
            std::fs::read(dir.join(format!("snap-{gen1:06}.kdb"))).unwrap();

        // clean recovery, then snapshot again: the file must not churn
        let (mut store2, db2, report) =
            KnowledgeStore::open(&dir, reopen_codec).unwrap();
        assert_eq!(report.generation_loaded, Some(gen1));
        assert_eq!(report.wal_records_replayed, 0);
        let gen2 = store2.snapshot(&db2).unwrap();
        let bytes2 =
            std::fs::read(dir.join(format!("snap-{gen2:06}.kdb"))).unwrap();
        assert_eq!(
            bytes1, bytes2,
            "snapshot → recover → snapshot must be byte-stable ({name})"
        );
        std::fs::remove_dir_all(&dir).ok();
    }
}
