//! Cross-module integration tests: monitor → discovery → training →
//! on-line classification; plug-in Algorithm 1 against the live DB;
//! knowledge-zone persistence through a simulated restart; artifact
//! runtime vs native math equivalence.

use kermit::clustering::NativeDistance;
use kermit::coordinator::{Coordinator, CoordinatorConfig};
use kermit::knowledge::{KnowledgeZones, WorkloadDb};
use kermit::ml::Classifier;
use kermit::monitor::{aggregate_trace, MonitorConfig};
use kermit::offline::{discover, train, DiscoveryConfig, TrainingConfig};
use kermit::online::{ChoiceKind, UNKNOWN};
use kermit::simcluster::JobSpec;
use kermit::util::rng::Rng;
use kermit::workloadgen::{tour_schedule, Generator, Mix};

#[test]
fn full_pipeline_monitor_to_classifier() {
    // generate -> monitor -> discover -> train -> classify a NEW trace
    let mut g = Generator::with_default_config(100);
    let trace = g.generate(&tour_schedule(400, &[1, 4, 6]));
    let mcfg = MonitorConfig { window_size: 30 };
    let windows = aggregate_trace(&trace, &mcfg);

    let mut db = WorkloadDb::new();
    let report = discover(
        &windows,
        &mut db,
        &DiscoveryConfig::default(),
        &NativeDistance,
    );
    assert_eq!(report.new_labels().len(), 3);

    let mut rng = Rng::new(101);
    let models = train(
        &windows,
        &report,
        &mut db,
        &TrainingConfig::default(),
        &mut rng,
    );

    // fresh trace, same classes: classification must be internally
    // consistent (same generator class -> same predicted label)
    let mut g2 = Generator::with_default_config(999);
    let t2 = g2.generate(&tour_schedule(200, &[1, 4, 6]));
    let w2 = aggregate_trace(&t2, &mcfg);
    let mut truth_to_pred: std::collections::BTreeMap<u32, Vec<u32>> =
        Default::default();
    for w in &w2 {
        if let Some(t) = w.truth {
            let aw = kermit::features::AnalyticWindow::from_observation(w);
            truth_to_pred
                .entry(t)
                .or_default()
                .push(models.workload_forest.predict(&aw.features));
        }
    }
    let mut seen_labels = std::collections::BTreeSet::new();
    for (t, preds) in &truth_to_pred {
        let first = preds[0];
        let agree =
            preds.iter().filter(|&&p| p == first).count() as f64
                / preds.len() as f64;
        assert!(agree > 0.9, "class {t}: only {agree} agreement");
        assert!(seen_labels.insert(first), "two classes share label {first}");
    }
}

#[test]
fn plugin_algorithm1_full_state_machine() {
    // UNKNOWN -> default; discovered -> global search -> cache hit;
    // drift -> local search -> cache hit again
    use kermit::knowledge::Characterization;
    use kermit::online::{ContextStream, KermitPlugin};
    use kermit::simcluster::perfmodel::job_duration;
    use std::sync::{Arc, Mutex, RwLock};

    let db = Arc::new(RwLock::new(WorkloadDb::new()));
    let ctx = Arc::new(Mutex::new(ContextStream::new(8)));
    let mut plugin = KermitPlugin::new(db.clone(), ctx);
    plugin.explorer_config.global_budget = 30;
    plugin.explorer_config.local_budget = 10;

    // phase 1: unknown
    let (c, kind) = plugin.choose_config_for_label(UNKNOWN);
    assert_eq!(kind, ChoiceKind::Default);
    assert_eq!(c, kermit::simcluster::default_config_index());

    // phase 2: discovery inserts the workload
    let label = {
        let rows: Vec<Vec<f64>> = vec![vec![5.0; 8], vec![5.2; 8]];
        let ch = Characterization::from_vec_rows(&rows);
        let cen = ch.mean_vector();
        db.write().unwrap().insert_new(ch, cen, 2, false)
    };

    // phase 3: global search until convergence
    let mut probes = 0;
    loop {
        let (ci, kind) = plugin.choose_config_for_label(label);
        match kind {
            ChoiceKind::GlobalProbe => {
                probes += 1;
                assert!(probes <= 30);
                plugin
                    .record_measurement(label, job_duration(4, &ci.to_config()));
            }
            ChoiceKind::CacheHit => break,
            other => panic!("unexpected {other:?}"),
        }
    }
    assert!(db.read().unwrap().get(label).unwrap().optimal_config_found);

    // phase 4: drift -> local search from the stored config
    {
        let mut dbl = db.write().unwrap();
        let rows: Vec<Vec<f64>> = vec![vec![9.0; 8], vec![9.2; 8]];
        let ch = Characterization::from_vec_rows(&rows);
        let cen = ch.mean_vector();
        dbl.mark_drifting(label, ch, cen, 2);
    }
    let (_, kind) = plugin.choose_config_for_label(label);
    assert_eq!(kind, ChoiceKind::LocalProbe);
    // drive local search to completion
    let mut steps = 0;
    plugin.record_measurement(label, 50.0);
    loop {
        let (ci, kind) = plugin.choose_config_for_label(label);
        match kind {
            ChoiceKind::LocalProbe => {
                steps += 1;
                assert!(steps <= 12);
                plugin
                    .record_measurement(label, job_duration(4, &ci.to_config()));
            }
            ChoiceKind::CacheHit => break,
            other => panic!("unexpected {other:?}"),
        }
    }
    let dbl = db.read().unwrap();
    let e = dbl.get(label).unwrap();
    assert!(e.optimal_config_found && !e.is_drifting);
}

#[test]
fn knowledge_survives_restart() {
    let dir = std::env::temp_dir().join("kermit_it_restart");
    std::fs::remove_dir_all(&dir).ok();
    let zones = KnowledgeZones::create(&dir).unwrap();

    // session 1: discover and persist
    let mut g = Generator::with_default_config(7);
    let trace = g.generate(&tour_schedule(300, &[2, 8]));
    let windows =
        aggregate_trace(&trace, &MonitorConfig { window_size: 30 });
    zones.append_windows(&windows).unwrap();
    let mut db = WorkloadDb::new();
    let r1 = discover(
        &windows,
        &mut db,
        &DiscoveryConfig::default(),
        &NativeDistance,
    );
    assert_eq!(r1.new_labels().len(), 2);
    db.save(&zones.workload_db_path()).unwrap();

    // session 2 (restart): reload zones + db, re-discover same classes
    let db2_windows = zones.read_windows().unwrap();
    assert_eq!(db2_windows.len(), windows.len());
    let mut db2 = WorkloadDb::load(&zones.workload_db_path()).unwrap();
    let t2 = g.generate(&tour_schedule(300, &[8, 2]));
    let w2 = aggregate_trace(&t2, &MonitorConfig { window_size: 30 });
    let r2 = discover(
        &w2,
        &mut db2,
        &DiscoveryConfig::default(),
        &NativeDistance,
    );
    assert!(
        r2.new_labels().is_empty(),
        "restart lost workload identity: {:?}",
        r2.outcomes
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn coordinator_closed_loop_converges() {
    let mut cfg = CoordinatorConfig::default();
    cfg.offline_interval_windows = 12;
    cfg.engine.duration_noise = 0.01;
    let mut coord = Coordinator::new(cfg);
    coord.plugin.explorer_config.global_budget = 20;
    let jobs: Vec<JobSpec> = (0..80)
        .map(|i| JobSpec { mix: Mix::Pure([0u32, 5][i % 2]) })
        .collect();
    let report = coord.run_schedule(&jobs);
    // both classes learned, searches finished, cache hits dominate tail
    assert!(report.plugin_stats.searches_completed >= 2);
    let tail_hits = report.jobs[60..]
        .iter()
        .filter(|j| j.choice == ChoiceKind::CacheHit)
        .count();
    assert!(tail_hits >= 15, "only {tail_hits} cache hits in tail");
    assert!(report.classification_consistency() > 0.9);
}

#[test]
fn drift_recovery_in_closed_loop() {
    // converge on a class, inject signature drift mid-run (the paper's
    // §6.1 drift / §6.2 node-failure scenario), and verify the autonomic
    // response: Algorithm 2 flags drift -> Algorithm 1 runs a LOCAL
    // search from the stored config -> system returns to cache hits.
    let mut cfg = CoordinatorConfig::default();
    cfg.offline_interval_windows = 12;
    cfg.engine.duration_noise = 0.01;
    // drift threshold low enough that the injected shift trips it
    cfg.discovery.drift_epsilon = 6.0;
    let mut coord = Coordinator::new(cfg);
    coord.plugin.explorer_config.global_budget = 20;
    coord.plugin.explorer_config.local_budget = 8;

    // phase 1: converge on classes 0 and 5
    let phase1: Vec<JobSpec> = (0..50)
        .map(|i| JobSpec { mix: Mix::Pure([0u32, 5][i % 2]) })
        .collect();
    let r1 = coord.run_schedule(&phase1);
    assert!(r1.plugin_stats.searches_completed >= 2, "{:?}", r1.plugin_stats);

    // phase 2: drift class 0's signature — far enough that the drifted
    // cluster separates cleanly from the stored one (beyond DBSCAN eps
    // and ε) yet still inside the match radius
    let mut shift = [0.0; kermit::features::NUM_FEATURES];
    shift[0] = 13.0;
    shift[3] = 11.0;
    shift[5] = 8.0;
    coord.inject_drift(0, shift);
    let phase2: Vec<JobSpec> = (0..40)
        .map(|i| JobSpec { mix: Mix::Pure([0u32, 5][i % 2]) })
        .collect();
    let r2 = coord.run_schedule(&phase2);

    // the local (drift) search must have run...
    assert!(
        r2.plugin_stats.local_probes > 0,
        "no local search after drift: {:?}",
        r2.plugin_stats
    );
    // ...and the system must be back to serving cached optima by the end
    let tail_hits = r2.jobs[30..]
        .iter()
        .filter(|j| j.choice == ChoiceKind::CacheHit)
        .count();
    assert!(tail_hits >= 6, "only {tail_hits} cache hits after recovery");
    // and the DB entry is no longer flagged drifting
    let db = coord.db.read().unwrap();
    assert!(db.entries().filter(|e| !e.synthetic).all(|e| !e.is_drifting));
}

#[test]
fn artifact_runtime_equivalent_to_native_welch() {
    // the welch_stats artifact and stats::welch agree end-to-end
    let rt = match kermit::runtime::Runtime::load(std::path::Path::new(
        "artifacts",
    )) {
        Ok(rt) => rt,
        Err(_) => return, // artifacts not built; covered elsewhere
    };
    use kermit::runtime::{literal_f32, shapes, to_f64_vec};
    let mut rng = Rng::new(3);
    let (w, s, f) = (
        shapes::WELCH_WINDOWS,
        shapes::WELCH_SAMPLES,
        shapes::NUM_FEATURES,
    );
    let xs: Vec<f64> =
        (0..w * s * f).map(|_| rng.normal_ms(10.0, 3.0)).collect();
    let art = rt.get("welch_stats").unwrap();
    let lit = literal_f32(&xs, &[w as i64, s as i64, f as i64]).unwrap();
    let out = art.run(&[lit]).unwrap();
    let mean = to_f64_vec(&out[0]).unwrap();
    let var = to_f64_vec(&out[1]).unwrap();

    // Welch t-test via artifact moments == via native moments
    let col = |wi: usize, fi: usize| -> Vec<f64> {
        (0..s).map(|si| xs[wi * s * f + si * f + fi]).collect()
    };
    for (wa, wb, fi) in [(0usize, 1usize, 0usize), (5, 6, 3), (62, 63, 15)] {
        let native = kermit::stats::welch_t_test(&col(wa, fi), &col(wb, fi));
        let nf = s as f64;
        let via_artifact = kermit::stats::welch_t_test_from_moments(
            mean[wa * f + fi],
            var[wa * f + fi] * nf / (nf - 1.0),
            s,
            mean[wb * f + fi],
            var[wb * f + fi] * nf / (nf - 1.0),
            s,
        );
        assert!(
            (native.t - via_artifact.t).abs() < 1e-3,
            "t: {} vs {}",
            native.t,
            via_artifact.t
        );
        assert!((native.p - via_artifact.p).abs() < 1e-3);
    }
}
