//! Golden-equivalence tests for the `linalg::engine` compute layer.
//!
//! Kernel tiers: the plain-`simd` AVX `sq_dist` kernel must be
//! bit-identical to the scalar kernel (build with `--features simd` to
//! exercise it — the CI simd job does). The `simd-fast` FMA tiers are
//! *tolerance-bounded* instead: within `SIMD_FAST_REL_TOL` of the
//! scalar kernel, and — pinned here on the golden fixtures — never
//! flipping a clustering/classification decision, only low-order
//! distance bits.
//!
//! Pool: every engine-parallel hot path must produce labels
//! bit-identical to its sequential counterpart whatever the thread
//! count, because the on-line / off-line split of the paper's loop
//! assumes discovery is a pure function of the landed windows, not of
//! the host's core count. The persistent-pool lifecycle (reuse across
//! thousands of calls, concurrent callers, shutdown/re-init, panic
//! recovery) is stress-tested at the bottom.

use kermit::clustering::kmeans::{kmeans, kmeans_with};
use kermit::clustering::{dbscan, dbscan_with, DbscanConfig};
use kermit::clustering::{DistanceProvider, EngineDistance, NativeDistance};
use kermit::linalg::engine::{self, Engine};
use kermit::linalg::Matrix;
use kermit::ml::forest::{ForestConfig, RandomForest};
use kermit::ml::knn::Knn;
use kermit::ml::{Classifier, Dataset};
use kermit::testkit::{forall, gen};
use kermit::util::rng::Rng;

fn par(threads: usize) -> Engine {
    // threshold dropped to 1 so even small generated cases actually fan
    // out instead of taking the sequential fallback
    Engine::with_threads(threads).with_min_items(1)
}

// With `simd-fast` the dispatch kernel is allowed to differ from the
// scalar kernel in low-order bits, so bit equality only holds for the
// default and plain-`simd` tiers; the fast tiers get the tolerance and
// label-stability suite below instead.
#[cfg(not(feature = "simd-fast"))]
#[test]
fn prop_simd_sq_dist_matches_scalar_lengths_0_to_64() {
    forall(
        20,
        200,
        |rng| {
            let n = rng.range_usize(0, 65);
            (gen::vec_f64(rng, n, -1e3, 1e3), gen::vec_f64(rng, n, -1e3, 1e3))
        },
        |(a, b)| {
            // exact bit equality, not a tolerance: the AVX kernel runs
            // the scalar accumulator sequence per lane (no FMA) and
            // reduces in the same order
            let fast = kermit::linalg::sq_dist(a, b);
            let scalar = engine::sq_dist_scalar(a, b);
            if fast.to_bits() != scalar.to_bits() {
                return Err(format!("simd {fast} != scalar {scalar}"));
            }
            Ok(())
        },
    );
}

#[cfg(feature = "simd-fast")]
mod simd_fast {
    use super::*;
    use kermit::clustering::NOISE;
    use kermit::linalg::engine::SIMD_FAST_REL_TOL;
    use kermit::linalg::sq_dist;

    #[test]
    fn prop_fast_sq_dist_within_documented_tolerance() {
        // the shipped contract: relative error bounded by
        // SIMD_FAST_REL_TOL against the scalar kernel (exact when the
        // runtime dispatch fell back to a non-FMA kernel). Lengths past
        // 64 exercise the 8-wide AVX-512 main loop + remainder.
        forall(
            24,
            300,
            |rng| {
                let n = rng.range_usize(0, 200);
                (gen::vec_f64(rng, n, -1e3, 1e3), gen::vec_f64(rng, n, -1e3, 1e3))
            },
            |(a, b)| {
                let fast = sq_dist(a, b);
                let scalar = engine::sq_dist_scalar(a, b);
                let bound = SIMD_FAST_REL_TOL * scalar.max(f64::MIN_POSITIVE);
                if (fast - scalar).abs() > bound {
                    return Err(format!(
                        "tier {}: |{fast} - {scalar}| > {bound}",
                        engine::simd_tier()
                    ));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_fast_sq_dist_bitwise_symmetric_and_zero_on_self() {
        // symmetry is what the parallel pairwise matrix relies on, and
        // it must survive the FMA kernels (squaring a sign-flipped
        // difference is sign-invariant); d(x,x) stays exactly 0
        forall(
            25,
            100,
            |rng| {
                let n = rng.range_usize(0, 130);
                (gen::vec_f64(rng, n, -50.0, 50.0), gen::vec_f64(rng, n, -50.0, 50.0))
            },
            |(a, b)| {
                if sq_dist(a, b).to_bits() != sq_dist(b, a).to_bits() {
                    return Err("asymmetric".into());
                }
                if sq_dist(a, a) != 0.0 {
                    return Err(format!("d(a,a) = {}", sq_dist(a, a)));
                }
                Ok(())
            },
        );
    }

    /// The golden kmeans fixture of `clustering::kmeans`'s own tests:
    /// three well-separated blobs whose decision margins dwarf the
    /// low-order-bit kernel differences.
    fn golden_blobs() -> Matrix {
        let mut rng = Rng::new(0);
        let mut rows = Matrix::with_width(2);
        for (cx, cy) in [(0.0, 0.0), (10.0, 0.0), (0.0, 10.0)] {
            for _ in 0..50 {
                rows.push_row(&[rng.normal_ms(cx, 0.5), rng.normal_ms(cy, 0.5)]);
            }
        }
        rows
    }

    #[test]
    fn fast_kernel_never_flips_kmeans_decisions_on_golden_fixture() {
        let rows = golden_blobs();
        let mut rng = Rng::new(3);
        let r = kmeans(&rows, 3, 100, &mut rng);
        // end-to-end label stability: each ground-truth blob still maps
        // to exactly one cluster under the fast kernel
        for g in 0..3 {
            let ls = &r.labels[g * 50..(g + 1) * 50];
            assert!(ls.iter().all(|&l| l == ls[0]), "blob {g} split");
        }
        // decision-level stability: the assign argmin is identical
        // whether distances come from the fast dispatch kernel or the
        // scalar reference — the margins absorb the low-order bits
        for row in rows.iter_rows() {
            let argmin = |d: &dyn Fn(&[f64], &[f64]) -> f64| {
                (0..r.centroids.n_rows())
                    .map(|c| (c, d(row, r.centroids.row(c))))
                    .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
                    .unwrap()
                    .0
            };
            assert_eq!(
                argmin(&sq_dist),
                argmin(&engine::sq_dist_scalar),
                "assign decision flipped (tier {})",
                engine::simd_tier()
            );
        }
    }

    #[test]
    fn fast_kernel_never_flips_dbscan_decisions_on_golden_fixture() {
        let rows = golden_blobs();
        let cfg = DbscanConfig { eps: 2.0, min_pts: 4 };
        // every ε-neighbourhood decision matches the scalar kernel on
        // the fixture (no pair sits within one ULP of the threshold)
        let eps_sq = cfg.eps * cfg.eps;
        let n = rows.n_rows();
        for i in 0..n {
            for j in 0..n {
                let fast = sq_dist(rows.row(i), rows.row(j)) <= eps_sq;
                let scalar =
                    engine::sq_dist_scalar(rows.row(i), rows.row(j)) <= eps_sq;
                assert_eq!(fast, scalar, "ε decision flipped at ({i}, {j})");
            }
        }
        // and the end-to-end structure is the expected one: 3 clusters,
        // each blob uniformly labelled, no noise
        let res = dbscan(&rows, &cfg, &NativeDistance);
        assert_eq!(res.n_clusters, 3);
        for g in 0..3 {
            let ls = &res.labels[g * 50..(g + 1) * 50];
            assert!(ls.iter().all(|&l| l == ls[0] && l != NOISE), "blob {g}");
        }
    }
}

#[test]
fn prop_pairwise_matrix_parallel_matches_sequential() {
    forall(
        21,
        25,
        |rng| {
            let n = rng.range_usize(2, 150);
            let w = rng.range_usize(1, 9);
            (gen::rows(rng, n, w, -50.0, 50.0), rng.range_usize(2, 9))
        },
        |(rows, threads)| {
            let m = Matrix::from_rows(rows);
            let want = NativeDistance.pairwise_sq(&m);
            let got = EngineDistance::new(par(*threads)).pairwise_sq(&m);
            if got != want {
                return Err(format!("diverged at {threads} threads"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_kmeans_parallel_labels_match_sequential() {
    forall(
        22,
        15,
        |rng| {
            let n = rng.range_usize(64, 220);
            let w = rng.range_usize(2, 7);
            (
                gen::rows(rng, n, w, -30.0, 30.0),
                rng.range_usize(1, 6),
                rng.range_usize(2, 9),
                rng.next_u64(),
            )
        },
        |(rows, k, threads, seed)| {
            let m = Matrix::from_rows(rows);
            let mut ra = Rng::new(*seed);
            let a = kmeans(&m, *k, 40, &mut ra);
            let mut rb = Rng::new(*seed);
            let b = kmeans_with(par(*threads), &m, *k, 40, &mut rb);
            if a.labels != b.labels {
                return Err(format!("labels diverged ({threads} threads)"));
            }
            if a.centroids != b.centroids {
                return Err("centroids diverged".into());
            }
            if a.inertia.to_bits() != b.inertia.to_bits() {
                return Err(format!("inertia {} vs {}", a.inertia, b.inertia));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_dbscan_parallel_labels_match_sequential() {
    forall(
        23,
        15,
        |rng| {
            let n = rng.range_usize(5, 180);
            let w = rng.range_usize(2, 7);
            (
                gen::rows(rng, n, w, -20.0, 20.0),
                rng.range_f64(0.5, 15.0),
                rng.range_usize(2, 6),
                rng.range_usize(2, 9),
            )
        },
        |(rows, eps, min_pts, threads)| {
            let m = Matrix::from_rows(rows);
            let cfg = DbscanConfig { eps: *eps, min_pts: *min_pts };
            let a = dbscan(&m, &cfg, &NativeDistance);
            let engine = par(*threads);
            let b = dbscan_with(engine, &m, &cfg, &EngineDistance::new(engine));
            if a.labels != b.labels || a.n_clusters != b.n_clusters {
                return Err(format!("diverged at {threads} threads"));
            }
            Ok(())
        },
    );
}

#[test]
fn forest_parallel_fit_and_predict_batch_match_sequential() {
    // seeded blobs; both the parallel tree fitting and the parallel
    // batch prediction must reproduce the sequential labels exactly
    let mut rng = Rng::new(31);
    let mut data = Dataset::new();
    for _ in 0..120 {
        for (label, cx) in [(0u32, 0.0), (1, 6.0), (2, -6.0)] {
            data.push(vec![rng.normal_ms(cx, 1.0), rng.normal_ms(cx / 2.0, 1.0)], label);
        }
    }
    let cfg = ForestConfig { n_trees: 20, ..Default::default() };
    let mut ra = Rng::new(77);
    let seq_forest = RandomForest::fit(&data, cfg.clone(), &mut ra);
    let seq_preds = seq_forest.predict_batch(data.x());
    for threads in [2, 3, 8] {
        let engine = par(threads);
        let mut rb = Rng::new(77);
        let par_forest = RandomForest::fit_with(&data, cfg.clone(), &mut rb, engine);
        assert_eq!(
            seq_preds,
            par_forest.predict_batch(data.x()),
            "parallel fit diverged at {threads} threads"
        );
        assert_eq!(
            seq_preds,
            seq_forest.predict_batch_with(engine, data.x()),
            "parallel predict_batch diverged at {threads} threads"
        );
    }
}

#[test]
fn knn_parallel_predict_batch_matches_sequential() {
    let mut rng = Rng::new(41);
    let mut data = Dataset::new();
    for _ in 0..100 {
        data.push(vec![rng.normal_ms(0.0, 1.0), rng.normal_ms(0.0, 1.0)], 0);
        data.push(vec![rng.normal_ms(4.0, 1.0), rng.normal_ms(4.0, 1.0)], 1);
    }
    let knn = Knn::fit(&data, 7);
    let seq = knn.predict_batch(data.x());
    for threads in [2, 5] {
        assert_eq!(seq, knn.predict_batch_with(par(threads), data.x()), "threads {threads}");
    }
}

// ---------------------------------------------------------------------------
// persistent-pool lifecycle
// ---------------------------------------------------------------------------

#[test]
fn pool_reuse_many_small_calls_back_to_back() {
    // the spawn-amortization case: 1000 tiny dispatches reuse the same
    // parked workers and stay exact (each round's additions land once)
    let engine = par(4);
    let n = 96usize;
    let mut acc = vec![0.0f64; n];
    for round in 0..1000usize {
        engine.for_rows(&mut acc, 1, |start, chunk| {
            for (off, cell) in chunk.iter_mut().enumerate() {
                *cell += (start + off + round) as f64;
            }
        });
    }
    for (i, &v) in acc.iter().enumerate() {
        let want: f64 = (0..1000).map(|r| (i + r) as f64).sum();
        assert_eq!(v, want, "item {i}");
    }
}

#[test]
fn pool_serves_concurrent_callers() {
    // several threads dispatching into the shared pool simultaneously:
    // no cross-talk between jobs, every caller sees its own results
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..4usize)
            .map(|t| {
                s.spawn(move || {
                    let engine = par(3);
                    let mut out = vec![0usize; 257];
                    for _ in 0..50 {
                        engine.for_rows(&mut out, 1, |start, chunk| {
                            for (off, cell) in chunk.iter_mut().enumerate() {
                                *cell = start + off + t;
                            }
                        });
                    }
                    out
                })
            })
            .collect();
        for (t, h) in handles.into_iter().enumerate() {
            let got = h.join().unwrap();
            let want: Vec<usize> = (0..257).map(|i| i + t).collect();
            assert_eq!(got, want, "caller {t} corrupted");
        }
    });
}

#[test]
fn pool_shutdown_and_reinit() {
    // engines are Copy handles: dropping them leaves the pool parked
    // and reusable; an explicit shutdown drains it, and the next
    // parallel call lazily re-initializes a fresh pool with identical
    // results. (Safe against concurrent tests: in-flight callers drain
    // their own jobs, later calls re-init.)
    let run = |engine: Engine| -> Vec<f64> {
        let mut out = vec![0.0f64; 500];
        engine.for_rows(&mut out, 1, |start, chunk| {
            for (off, cell) in chunk.iter_mut().enumerate() {
                let x = (start + off) as f64;
                *cell = (x * 0.7).cos() + x;
            }
        });
        out
    };
    let before = {
        let engine = par(4);
        run(engine)
    }; // engine handle dropped while the pool sits idle
    kermit::linalg::pool::shutdown();
    let after = run(par(4)); // lazily re-initialized
    assert_eq!(before, after, "results changed across shutdown/re-init");
    // (no worker_count == 0 assertion after shutdown: sibling tests in
    // this binary run concurrently and may re-grow the pool at any
    // point — shutdown correctness is the identical results above)
    kermit::linalg::pool::shutdown();
    // and sequential engines keep working with no pool at all
    assert_eq!(before, run(Engine::sequential()));
}

#[test]
fn pool_worker_panic_propagates_without_poisoning() {
    let engine = par(4);
    let boom = std::panic::catch_unwind(|| {
        let mut out = vec![0u8; 128];
        engine.for_rows(&mut out, 1, |start, _chunk| {
            if start >= 64 {
                panic!("chunk boom");
            }
        });
    });
    assert!(boom.is_err(), "worker panic did not reach the caller");
    // the pool keeps serving: same engine handle, correct results
    for _ in 0..20 {
        let mut out = vec![0usize; 333];
        engine.for_rows(&mut out, 1, |start, chunk| {
            for (off, cell) in chunk.iter_mut().enumerate() {
                *cell = start + off;
            }
        });
        assert_eq!(out, (0..333).collect::<Vec<_>>(), "pool poisoned");
    }
}

#[test]
fn kmeans_duplicate_ties_stay_deterministic_across_thread_counts() {
    // all-duplicate rows: every assign distance ties at 0 and every
    // update empties k-1 clusters, forcing the reseed argmax through
    // its tie-breaking on each iteration
    let rows = Matrix::from_rows(&vec![vec![2.0, 3.0, 4.0]; 256]);
    let mut ra = Rng::new(13);
    let a = kmeans(&rows, 4, 12, &mut ra);
    for threads in [2, 3, 7, 16] {
        let mut rb = Rng::new(13);
        let b = kmeans_with(par(threads), &rows, 4, 12, &mut rb);
        assert_eq!(a.labels, b.labels, "threads {threads}");
        assert_eq!(a.centroids, b.centroids, "threads {threads}");
        assert_eq!(a.iterations, b.iterations, "threads {threads}");
    }
}
