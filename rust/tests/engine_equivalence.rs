//! Golden-equivalence tests for the `linalg::engine` compute layer: the
//! SIMD `sq_dist` kernel must be bit-identical to the scalar kernel
//! (build with `--features simd` to exercise the AVX path — the CI simd
//! job does), and every engine-parallel hot path must produce labels
//! bit-identical to its sequential counterpart, because the on-line /
//! off-line split of the paper's loop assumes discovery is a pure
//! function of the landed windows, not of the host's core count.

use kermit::clustering::kmeans::{kmeans, kmeans_with};
use kermit::clustering::{dbscan, dbscan_with, DbscanConfig};
use kermit::clustering::{DistanceProvider, EngineDistance, NativeDistance};
use kermit::linalg::engine::{self, Engine};
use kermit::linalg::Matrix;
use kermit::ml::forest::{ForestConfig, RandomForest};
use kermit::ml::knn::Knn;
use kermit::ml::{Classifier, Dataset};
use kermit::testkit::{forall, gen};
use kermit::util::rng::Rng;

fn par(threads: usize) -> Engine {
    // threshold dropped to 1 so even small generated cases actually fan
    // out instead of taking the sequential fallback
    Engine::with_threads(threads).with_min_items(1)
}

#[test]
fn prop_simd_sq_dist_matches_scalar_lengths_0_to_64() {
    forall(
        20,
        200,
        |rng| {
            let n = rng.range_usize(0, 65);
            (gen::vec_f64(rng, n, -1e3, 1e3), gen::vec_f64(rng, n, -1e3, 1e3))
        },
        |(a, b)| {
            // exact bit equality, not a tolerance: the AVX kernel runs
            // the scalar accumulator sequence per lane (no FMA) and
            // reduces in the same order
            let fast = kermit::linalg::sq_dist(a, b);
            let scalar = engine::sq_dist_scalar(a, b);
            if fast.to_bits() != scalar.to_bits() {
                return Err(format!("simd {fast} != scalar {scalar}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_pairwise_matrix_parallel_matches_sequential() {
    forall(
        21,
        25,
        |rng| {
            let n = rng.range_usize(2, 150);
            let w = rng.range_usize(1, 9);
            (gen::rows(rng, n, w, -50.0, 50.0), rng.range_usize(2, 9))
        },
        |(rows, threads)| {
            let m = Matrix::from_rows(rows);
            let want = NativeDistance.pairwise_sq(&m);
            let got = EngineDistance::new(par(*threads)).pairwise_sq(&m);
            if got != want {
                return Err(format!("diverged at {threads} threads"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_kmeans_parallel_labels_match_sequential() {
    forall(
        22,
        15,
        |rng| {
            let n = rng.range_usize(64, 220);
            let w = rng.range_usize(2, 7);
            (
                gen::rows(rng, n, w, -30.0, 30.0),
                rng.range_usize(1, 6),
                rng.range_usize(2, 9),
                rng.next_u64(),
            )
        },
        |(rows, k, threads, seed)| {
            let m = Matrix::from_rows(rows);
            let mut ra = Rng::new(*seed);
            let a = kmeans(&m, *k, 40, &mut ra);
            let mut rb = Rng::new(*seed);
            let b = kmeans_with(par(*threads), &m, *k, 40, &mut rb);
            if a.labels != b.labels {
                return Err(format!("labels diverged ({threads} threads)"));
            }
            if a.centroids != b.centroids {
                return Err("centroids diverged".into());
            }
            if a.inertia.to_bits() != b.inertia.to_bits() {
                return Err(format!("inertia {} vs {}", a.inertia, b.inertia));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_dbscan_parallel_labels_match_sequential() {
    forall(
        23,
        15,
        |rng| {
            let n = rng.range_usize(5, 180);
            let w = rng.range_usize(2, 7);
            (
                gen::rows(rng, n, w, -20.0, 20.0),
                rng.range_f64(0.5, 15.0),
                rng.range_usize(2, 6),
                rng.range_usize(2, 9),
            )
        },
        |(rows, eps, min_pts, threads)| {
            let m = Matrix::from_rows(rows);
            let cfg = DbscanConfig { eps: *eps, min_pts: *min_pts };
            let a = dbscan(&m, &cfg, &NativeDistance);
            let engine = par(*threads);
            let b = dbscan_with(engine, &m, &cfg, &EngineDistance::new(engine));
            if a.labels != b.labels || a.n_clusters != b.n_clusters {
                return Err(format!("diverged at {threads} threads"));
            }
            Ok(())
        },
    );
}

#[test]
fn forest_parallel_fit_and_predict_batch_match_sequential() {
    // seeded blobs; both the parallel tree fitting and the parallel
    // batch prediction must reproduce the sequential labels exactly
    let mut rng = Rng::new(31);
    let mut data = Dataset::new();
    for _ in 0..120 {
        for (label, cx) in [(0u32, 0.0), (1, 6.0), (2, -6.0)] {
            data.push(vec![rng.normal_ms(cx, 1.0), rng.normal_ms(cx / 2.0, 1.0)], label);
        }
    }
    let cfg = ForestConfig { n_trees: 20, ..Default::default() };
    let mut ra = Rng::new(77);
    let seq_forest = RandomForest::fit(&data, cfg.clone(), &mut ra);
    let seq_preds = seq_forest.predict_batch(data.x());
    for threads in [2, 3, 8] {
        let engine = par(threads);
        let mut rb = Rng::new(77);
        let par_forest = RandomForest::fit_with(&data, cfg.clone(), &mut rb, engine);
        assert_eq!(
            seq_preds,
            par_forest.predict_batch(data.x()),
            "parallel fit diverged at {threads} threads"
        );
        assert_eq!(
            seq_preds,
            seq_forest.predict_batch_with(engine, data.x()),
            "parallel predict_batch diverged at {threads} threads"
        );
    }
}

#[test]
fn knn_parallel_predict_batch_matches_sequential() {
    let mut rng = Rng::new(41);
    let mut data = Dataset::new();
    for _ in 0..100 {
        data.push(vec![rng.normal_ms(0.0, 1.0), rng.normal_ms(0.0, 1.0)], 0);
        data.push(vec![rng.normal_ms(4.0, 1.0), rng.normal_ms(4.0, 1.0)], 1);
    }
    let knn = Knn::fit(&data, 7);
    let seq = knn.predict_batch(data.x());
    for threads in [2, 5] {
        assert_eq!(seq, knn.predict_batch_with(par(threads), data.x()), "threads {threads}");
    }
}

#[test]
fn kmeans_duplicate_ties_stay_deterministic_across_thread_counts() {
    // all-duplicate rows: every assign distance ties at 0 and every
    // update empties k-1 clusters, forcing the reseed argmax through
    // its tie-breaking on each iteration
    let rows = Matrix::from_rows(&vec![vec![2.0, 3.0, 4.0]; 256]);
    let mut ra = Rng::new(13);
    let a = kmeans(&rows, 4, 12, &mut ra);
    for threads in [2, 3, 7, 16] {
        let mut rb = Rng::new(13);
        let b = kmeans_with(par(threads), &rows, 4, 12, &mut rb);
        assert_eq!(a.labels, b.labels, "threads {threads}");
        assert_eq!(a.centroids, b.centroids, "threads {threads}");
        assert_eq!(a.iterations, b.iterations, "threads {threads}");
    }
}
