//! Golden-equivalence tests for the contiguous-storage migration: the
//! `Matrix`-based kmeans / DBSCAN / kNN must produce exactly the labels
//! the pre-refactor `Vec<Vec<f64>>` implementations produced on seeded
//! blob fixtures.
//!
//! Each reference implementation below is a verbatim port of the
//! pre-migration algorithm over nested-Vec rows (same RNG probe
//! sequence, same update arithmetic), with distances computed through
//! the same `linalg::sq_dist` kernel so float summation order is
//! identical and label comparisons can be exact.

use kermit::clustering::kmeans::kmeans;
use kermit::clustering::{dbscan, DbscanConfig, NativeDistance};
use kermit::linalg::{sq_dist, Matrix};
use kermit::ml::{Classifier, Dataset};
use kermit::ml::knn::Knn;
use kermit::util::rng::Rng;
use std::collections::BTreeMap;

fn blob_rows(
    seed: u64,
    centers: &[(f64, f64)],
    per_center: usize,
    spread: f64,
) -> Vec<Vec<f64>> {
    let mut rng = Rng::new(seed);
    let mut rows = Vec::new();
    for &(cx, cy) in centers {
        for _ in 0..per_center {
            rows.push(vec![
                rng.normal_ms(cx, spread),
                rng.normal_ms(cy, spread),
            ]);
        }
    }
    rows
}

// ---------------------------------------------------------------------------
// reference: pre-refactor kmeans (k-means++ init + Lloyd over Vec rows)
// ---------------------------------------------------------------------------

fn ref_kmeans(
    rows: &[Vec<f64>],
    k: usize,
    max_iter: usize,
    rng: &mut Rng,
) -> (Vec<i32>, Vec<Vec<f64>>) {
    let n = rows.len();
    let mut centroids: Vec<Vec<f64>> = Vec::with_capacity(k);
    centroids.push(rows[rng.range_usize(0, n)].clone());
    let mut d2: Vec<f64> =
        rows.iter().map(|r| sq_dist(r, &centroids[0])).collect();
    while centroids.len() < k {
        let total: f64 = d2.iter().sum();
        let next = if total <= 1e-18 {
            rng.range_usize(0, n)
        } else {
            let mut target = rng.f64() * total;
            let mut pick = n - 1;
            for (i, &w) in d2.iter().enumerate() {
                if target < w {
                    pick = i;
                    break;
                }
                target -= w;
            }
            pick
        };
        centroids.push(rows[next].clone());
        for (i, r) in rows.iter().enumerate() {
            let d = sq_dist(r, centroids.last().unwrap());
            if d < d2[i] {
                d2[i] = d;
            }
        }
    }

    let mut labels = vec![0i32; n];
    for it in 0..max_iter {
        let mut changed = false;
        for (i, r) in rows.iter().enumerate() {
            let best = centroids
                .iter()
                .enumerate()
                .map(|(c, cen)| (c, sq_dist(r, cen)))
                .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
                .unwrap()
                .0 as i32;
            if labels[i] != best {
                labels[i] = best;
                changed = true;
            }
        }
        let w = rows[0].len();
        let mut sums = vec![vec![0.0; w]; k];
        let mut counts = vec![0usize; k];
        for (i, r) in rows.iter().enumerate() {
            let c = labels[i] as usize;
            counts[c] += 1;
            for j in 0..w {
                sums[c][j] += r[j];
            }
        }
        for c in 0..k {
            if counts[c] > 0 {
                for j in 0..w {
                    centroids[c][j] = sums[c][j] / counts[c] as f64;
                }
            } else {
                let far = (0..n)
                    .max_by(|&a, &b| {
                        let da =
                            sq_dist(&rows[a], &centroids[labels[a] as usize]);
                        let db =
                            sq_dist(&rows[b], &centroids[labels[b] as usize]);
                        da.partial_cmp(&db).unwrap()
                    })
                    .unwrap();
                centroids[c] = rows[far].clone();
            }
        }
        if !changed && it > 0 {
            break;
        }
    }
    (labels, centroids)
}

#[test]
fn kmeans_labels_match_vec_of_vec_reference() {
    let rows =
        blob_rows(0, &[(0.0, 0.0), (10.0, 0.0), (0.0, 10.0)], 50, 0.5);
    let m = Matrix::from_rows(&rows);

    // identical RNG seed -> identical k-means++ probe sequence
    let mut rng_ref = Rng::new(7);
    let (ref_labels, ref_centroids) = ref_kmeans(&rows, 3, 100, &mut rng_ref);
    let mut rng_new = Rng::new(7);
    let r = kmeans(&m, 3, 100, &mut rng_new);

    assert_eq!(r.labels, ref_labels, "kmeans labels diverged");
    for (c, rc) in ref_centroids.iter().enumerate() {
        for (j, v) in rc.iter().enumerate() {
            assert!(
                (r.centroids.row(c)[j] - v).abs() < 1e-9,
                "centroid [{c}][{j}]: {} vs {v}",
                r.centroids.row(c)[j]
            );
        }
    }
    // inertia agrees with the reference assignment
    let ref_inertia: f64 = rows
        .iter()
        .zip(&ref_labels)
        .map(|(r, &l)| sq_dist(r, &ref_centroids[l as usize]))
        .sum();
    assert!((r.inertia - ref_inertia).abs() < 1e-6 * ref_inertia.max(1.0));
}

// ---------------------------------------------------------------------------
// reference: pre-refactor DBSCAN over a Vec<Vec<f64>> distance matrix
// ---------------------------------------------------------------------------

fn ref_dbscan(rows: &[Vec<f64>], eps: f64, min_pts: usize) -> Vec<i32> {
    let n = rows.len();
    if n == 0 {
        return vec![];
    }
    let mut d = vec![0.0; n * n];
    for i in 0..n {
        for j in (i + 1)..n {
            let v = sq_dist(&rows[i], &rows[j]);
            d[i * n + j] = v;
            d[j * n + i] = v;
        }
    }
    let eps_sq = eps * eps;
    let neighbours: Vec<Vec<usize>> = (0..n)
        .map(|i| (0..n).filter(|&j| d[i * n + j] <= eps_sq).collect())
        .collect();
    let is_core: Vec<bool> =
        neighbours.iter().map(|nb| nb.len() >= min_pts).collect();

    const UNVISITED: i32 = -2;
    const NOISE: i32 = -1;
    let mut labels = vec![UNVISITED; n];
    let mut cluster = 0i32;
    for i in 0..n {
        if labels[i] != UNVISITED || !is_core[i] {
            continue;
        }
        labels[i] = cluster;
        let mut queue: Vec<usize> = neighbours[i].clone();
        while let Some(j) = queue.pop() {
            if labels[j] == NOISE {
                labels[j] = cluster;
            }
            if labels[j] != UNVISITED {
                continue;
            }
            labels[j] = cluster;
            if is_core[j] {
                queue.extend(neighbours[j].iter().copied());
            }
        }
        cluster += 1;
    }
    for l in labels.iter_mut() {
        if *l == UNVISITED {
            *l = NOISE;
        }
    }
    labels
}

#[test]
fn dbscan_labels_match_vec_of_vec_reference() {
    for seed in [0u64, 1, 2] {
        let mut rows =
            blob_rows(seed, &[(0.0, 0.0), (8.0, 8.0)], 40, 0.4);
        rows.push(vec![4.0, 4.0]); // isolated point -> noise
        let m = Matrix::from_rows(&rows);
        let cfg = DbscanConfig { eps: 1.2, min_pts: 4 };
        let got = dbscan(&m, &cfg, &NativeDistance);
        let want = ref_dbscan(&rows, cfg.eps, cfg.min_pts);
        assert_eq!(got.labels, want, "dbscan diverged at seed {seed}");
    }
}

// ---------------------------------------------------------------------------
// reference: pre-refactor kNN (standardised Vec rows, distance-weighted)
// ---------------------------------------------------------------------------

struct RefKnn {
    k: usize,
    rows: Vec<Vec<f64>>,
    labels: Vec<u32>,
    moments: Vec<(f64, f64)>,
}

fn ref_feature_moments(rows: &[Vec<f64>]) -> Vec<(f64, f64)> {
    let w = rows[0].len();
    let n = rows.len() as f64;
    let mut out = vec![(0.0, 0.0); w];
    for row in rows {
        for (j, &v) in row.iter().enumerate() {
            out[j].0 += v;
        }
    }
    for m in out.iter_mut() {
        m.0 /= n;
    }
    for row in rows {
        for (j, &v) in row.iter().enumerate() {
            let d = v - out[j].0;
            out[j].1 += d * d;
        }
    }
    for m in out.iter_mut() {
        m.1 = (m.1 / n).sqrt();
        if m.1 < 1e-12 {
            m.1 = 1.0;
        }
    }
    out
}

fn ref_standardise(x: &[f64], moments: &[(f64, f64)]) -> Vec<f64> {
    x.iter().zip(moments).map(|(v, (m, s))| (v - m) / s).collect()
}

impl RefKnn {
    fn fit(rows: &[Vec<f64>], labels: &[u32], k: usize) -> RefKnn {
        let moments = ref_feature_moments(rows);
        let std_rows =
            rows.iter().map(|r| ref_standardise(r, &moments)).collect();
        RefKnn { k: k.max(1), rows: std_rows, labels: labels.to_vec(), moments }
    }

    fn predict(&self, x: &[f64]) -> u32 {
        let xs = ref_standardise(x, &self.moments);
        let mut dists: Vec<(f64, u32)> = self
            .rows
            .iter()
            .zip(&self.labels)
            .map(|(r, &l)| (sq_dist(r, &xs), l))
            .collect();
        let k = self.k.min(dists.len());
        dists.select_nth_unstable_by(k - 1, |a, b| {
            a.0.partial_cmp(&b.0).unwrap()
        });
        let mut votes: BTreeMap<u32, f64> = BTreeMap::new();
        for &(d, l) in &dists[..k] {
            *votes.entry(l).or_insert(0.0) += 1.0 / (d.sqrt() + 1e-9);
        }
        votes
            .into_iter()
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .map(|(c, _)| c)
            .unwrap()
    }
}

#[test]
fn knn_predictions_match_vec_of_vec_reference() {
    let rows = blob_rows(
        3,
        &[(0.0, 0.0), (6.0, 0.0), (0.0, 6.0)],
        40,
        0.8,
    );
    let labels: Vec<u32> =
        (0..3u32).flat_map(|c| std::iter::repeat(c).take(40)).collect();

    let mut data = Dataset::new();
    for (r, &l) in rows.iter().zip(&labels) {
        data.push(r, l);
    }
    let knn = Knn::fit(&data, 7);
    let reference = RefKnn::fit(&rows, &labels, 7);

    // probe a grid spanning the blobs, including ambiguous midpoints
    for ix in -2..=8 {
        for iy in -2..=8 {
            let p = [ix as f64, iy as f64];
            assert_eq!(
                knn.predict(&p),
                reference.predict(&p),
                "knn diverged at probe {p:?}"
            );
        }
    }
    // and on the training rows themselves
    let batch = knn.predict_batch(data.x());
    for (i, r) in rows.iter().enumerate() {
        assert_eq!(batch[i], reference.predict(r), "row {i}");
    }
}
