//! Ingest front-end contract pins: explicit backpressure is *never*
//! silent, shed decisions are deterministic, concurrent producers under
//! `Block` lose nothing, and the front-end path into the router is
//! label-for-label identical to feeding the router directly.

use std::time::Duration;

use kermit::linalg::engine::Engine;
use kermit::monitor::MonitorConfig;
use kermit::stream::fault::{SampleDelay, SampleDup, SampleLoss};
use kermit::stream::{
    IngestConfig, IngestFrontEnd, RouterConfig, ShedPolicy, StreamRouter,
    SubmitOutcome, TenantId, TenantSample, TransportFaultPlan,
    TransportLayer,
};
use kermit::workloadgen::{heavy_tailed_stream, Sample};

fn stream(seed: u64, tenants: usize, events: usize) -> Vec<(TenantId, Sample)> {
    heavy_tailed_stream(seed, tenants, events, 1.1, 4, &[0, 2, 5])
}

fn front_end(cap: usize, policy: ShedPolicy, wsize: usize) -> IngestFrontEnd {
    IngestFrontEnd::new(IngestConfig {
        queue_cap: cap,
        policy,
        monitor: MonitorConfig { window_size: wsize },
        drain_max: 0,
        engine: Engine::sequential(),
        ..IngestConfig::default()
    })
}

fn router(wsize: usize) -> StreamRouter {
    StreamRouter::new(RouterConfig {
        monitor: MonitorConfig { window_size: wsize },
        ..RouterConfig::default()
    })
}

/// Conservation property: for every policy, every tenant's counters
/// reconcile exactly — `accepted + shed + resident == submitted` — and
/// every accepted sample is either inside a closed window or still open
/// in the batcher. No path loses a sample without counting it.
#[test]
fn accepted_plus_shed_equals_submitted_for_every_policy() {
    let wsize = 5;
    let events = stream(11, 8, 400);
    for policy in
        [ShedPolicy::Block, ShedPolicy::ShedOldest, ShedPolicy::ShedNewest]
    {
        // Block gets headroom so the single-threaded driver never
        // parks itself; the shed arms get a tiny queue so the
        // heavy-tailed head tenant overflows between pumps.
        let cap = if policy == ShedPolicy::Block { 64 } else { 4 };
        let mut fe = front_end(cap, policy, wsize);
        let mut r = router(wsize);
        let h = fe.handle();
        let mut windows = 0u64;
        for (i, (t, s)) in events.iter().enumerate() {
            h.submit(*t, s.clone());
            if i % 16 == 15 {
                windows += fe.pump(&mut r).windows;
            }
        }
        windows += fe.pump(&mut r).windows;
        assert_eq!(fe.resident(), 0, "{policy:?}: drain left residue");

        for (t, st) in h.stats() {
            assert_eq!(
                st.accepted + st.shed + st.resident,
                st.submitted,
                "{policy:?}: tenant {t:?} leaked samples"
            );
            assert_eq!(st.resident, 0, "{policy:?}: tenant {t:?} resident");
        }
        let totals = h.totals();
        assert_eq!(totals.submitted, events.len() as u64);
        assert_eq!(
            windows * wsize as u64 + fe.open_samples() as u64,
            totals.accepted,
            "{policy:?}: accepted samples do not reconcile with windows"
        );
        match policy {
            ShedPolicy::Block => assert_eq!(totals.shed, 0),
            _ => assert!(
                totals.shed > 0,
                "{policy:?}: tiny queue under a heavy tail must shed"
            ),
        }
    }
}

/// Shed decisions are a pure function of the submit/pump sequence:
/// replaying the identical single-threaded schedule yields the same
/// per-submit outcomes, the same per-tenant counters, and the same
/// published label sequences.
#[test]
fn shed_decisions_are_deterministic_across_identical_runs() {
    for policy in [ShedPolicy::ShedOldest, ShedPolicy::ShedNewest] {
        let run = || {
            let wsize = 4;
            let events = stream(42, 6, 300);
            let mut fe = front_end(3, policy, wsize);
            let mut r = router(wsize);
            let h = fe.handle();
            let mut outcomes = Vec::with_capacity(events.len());
            for (i, (t, s)) in events.iter().enumerate() {
                outcomes.push(h.submit(*t, s.clone()));
                if i % 10 == 9 {
                    fe.pump(&mut r);
                }
            }
            fe.pump(&mut r);
            let labels: Vec<(TenantId, Vec<u32>)> = r
                .tenants()
                .into_iter()
                .map(|t| (t, r.shard(t).unwrap().label_log()))
                .collect();
            (outcomes, h.stats(), labels)
        };
        let a = run();
        let b = run();
        assert_eq!(a.0, b.0, "{policy:?}: outcome sequences diverged");
        assert_eq!(a.1, b.1, "{policy:?}: tenant stats diverged");
        assert_eq!(a.2, b.2, "{policy:?}: label logs diverged");
    }
}

/// Two producer threads hammering cloned handles under `Block` while
/// the main thread pumps: every sample is eventually accepted — the
/// tiny queue forces real blocking, and nothing is shed or lost.
#[test]
fn two_producers_under_block_lose_nothing() {
    let wsize = 6;
    let events = stream(7, 10, 1_000);
    let mut fe = front_end(8, ShedPolicy::Block, wsize);
    let mut r = router(wsize);
    let handle = fe.handle();
    let mut windows = 0u64;
    std::thread::scope(|s| {
        let producers: Vec<_> = (0..2)
            .map(|p| {
                let h = handle.clone();
                let ev = &events;
                s.spawn(move || {
                    for (t, sample) in ev.iter().skip(p).step_by(2) {
                        h.submit(*t, sample.clone());
                    }
                })
            })
            .collect();
        // 10 tenants x cap 8 = 80 queue slots for 1000 events, and no
        // pump has run yet: a producer is guaranteed to fill a queue
        // and park. Wait for that (`blocked` is counted *before* the
        // wait) so the test provably exercises Block, then drain.
        while handle.totals().blocked == 0 {
            std::thread::yield_now();
        }
        loop {
            let st = fe.pump(&mut r);
            windows += st.windows;
            if producers.iter().all(|p| p.is_finished())
                && fe.resident() == 0
            {
                break;
            }
            if st.drained == 0 {
                fe.wait_for_samples(Duration::from_millis(1));
            }
        }
    });
    let totals = handle.totals();
    assert_eq!(totals.submitted, events.len() as u64);
    assert_eq!(totals.shed, 0);
    assert_eq!(totals.accepted, events.len() as u64);
    assert!(totals.blocked > 0, "cap 8 under a hot tenant must block");
    for (t, st) in handle.stats() {
        assert_eq!(st.accepted, st.submitted, "tenant {t:?}");
        assert_eq!(st.resident, 0, "tenant {t:?}");
    }
    assert_eq!(
        windows * wsize as u64 + fe.open_samples() as u64,
        events.len() as u64
    );
}

/// The batched front-end path is equivalent to feeding the router
/// directly: same tenants, same per-tenant contexts, regardless of
/// where the pump boundaries fall.
#[test]
fn front_end_path_matches_direct_router_ingest() {
    let wsize = 5;
    let events = stream(23, 5, 600);

    let mut direct = router(wsize);
    for (t, s) in &events {
        direct
            .ingest_tagged(&TenantSample { tenant: *t, sample: s.clone() });
    }
    direct.tick();

    let mut fe = front_end(1_024, ShedPolicy::Block, wsize);
    let mut batched = router(wsize);
    let h = fe.handle();
    for (i, (t, s)) in events.iter().enumerate() {
        h.submit(*t, s.clone());
        if i % 37 == 36 {
            fe.pump(&mut batched);
        }
    }
    fe.pump(&mut batched);

    assert_eq!(h.totals().shed, 0);
    assert_eq!(batched.tenants(), direct.tenants());
    for t in batched.tenants() {
        let a = batched.shard(t).unwrap();
        let b = direct.shard(t).unwrap();
        assert_eq!(a.contexts, b.contexts, "tenant {t:?} contexts diverged");
    }
}

/// Closing the front-end wakes producers parked under `Block` with an
/// explicit [`SubmitOutcome::Closed`] — never a hang, never a silent
/// loss: the rejected samples are counted in `closed_rejects` and the
/// conservation invariant still reconciles exactly.
#[test]
fn close_while_blocked_reports_closed_not_hang() {
    let events = stream(3, 1, 8);
    let mut fe = front_end(2, ShedPolicy::Block, 4);
    let h = fe.handle();
    let (t0, s0) = events[0].clone();
    // fill the tiny queue, then park a producer on the third submit
    assert_eq!(h.submit(t0, s0.clone()), SubmitOutcome::Accepted);
    assert_eq!(h.submit(t0, s0.clone()), SubmitOutcome::Accepted);
    let blocked = {
        let h = h.clone();
        let s = s0.clone();
        std::thread::spawn(move || h.submit(t0, s))
    };
    while h.totals().blocked == 0 {
        std::thread::yield_now();
    }
    fe.close();
    assert_eq!(
        blocked.join().unwrap(),
        SubmitOutcome::Closed,
        "a blocked producer must wake with an explicit Closed"
    );
    // post-close submits are rejected the same way, not dropped silently
    assert_eq!(h.submit(t0, s0), SubmitOutcome::Closed);
    let st = h.totals();
    assert_eq!(st.closed_rejects, 2);
    assert_eq!(
        st.accepted + st.shed + st.deduped + st.closed_rejects + st.resident,
        st.submitted,
        "conservation must hold through close"
    );
}

/// Duplicated and reordered transport collapses back to exactly-once,
/// in-order delivery: the faulted path publishes contexts identical to
/// an in-order run of the same events, and every extra delivery lands
/// in `deduped` — the window accounting never double-counts.
#[test]
fn duplicated_reordered_transport_matches_in_order_ingest() {
    let wsize = 5;
    let events = stream(19, 4, 500);

    // in-order oracle through the same front-end machinery
    let mut fe_a = front_end(1 << 14, ShedPolicy::ShedOldest, wsize);
    let mut r_a = router(wsize);
    let h_a = fe_a.handle();
    for (i, (t, s)) in events.iter().enumerate() {
        h_a.submit(*t, s.clone());
        if i % 16 == 15 {
            fe_a.pump(&mut r_a);
        }
    }
    fe_a.pump(&mut r_a);

    // duplicating + delaying link (no loss), parked gaps never written
    // off so nothing can be mistaken for a transport drop mid-run
    let mut fe_b = IngestFrontEnd::new(IngestConfig {
        queue_cap: 1 << 14,
        policy: ShedPolicy::ShedOldest,
        monitor: MonitorConfig { window_size: wsize },
        gap_patience: 1_000,
        reorder_cap: 1 << 14,
        ..IngestConfig::default()
    });
    let mut r_b = router(wsize);
    let h_b = fe_b.handle();
    let mut link = TransportLayer::new(
        TransportFaultPlan {
            duplication: Some(SampleDup { prob: 0.4 }),
            delay: Some(SampleDelay { prob: 0.3, max_hold: 3 }),
            ..TransportFaultPlan::default()
        },
        99,
    );
    for (i, (t, s)) in events.iter().enumerate() {
        link.send(&h_b, *t, s.clone());
        if i % 16 == 15 {
            fe_b.pump(&mut r_b);
        }
    }
    link.flush(&h_b);
    fe_b.flush_transport(&mut r_b);
    fe_b.pump(&mut r_b); // tick the windows the settlement enqueued

    let dups = link.report.samples_duplicated as u64;
    assert!(dups > 0, "the link never duplicated anything");
    assert!(link.report.samples_delayed > 0, "the link never reordered");
    let st = h_b.totals();
    assert_eq!(st.deduped, dups, "every duplicate collapsed exactly once");
    assert_eq!(st.gaps_skipped, 0, "no real loss, so no write-offs");
    assert_eq!(st.submitted, events.len() as u64 + dups);
    assert_eq!(
        st.accepted + st.shed + st.deduped + st.closed_rejects + st.resident,
        st.submitted
    );
    // the label timeline is bit-identical to the in-order run
    assert_eq!(r_b.tenants(), r_a.tenants());
    for t in r_b.tenants() {
        assert_eq!(
            r_b.shard(t).unwrap().contexts,
            r_a.shard(t).unwrap().contexts,
            "tenant {t:?} contexts diverged under duplication/reorder"
        );
    }
}

/// The transport layer's ground-truth fault report reconciles with the
/// consumer-side counters: injected ≥ observed, delivery totals exact,
/// and nothing stays resident after the end-of-run flush.
#[test]
fn transport_ground_truth_reconciles_with_consumer_counters() {
    let wsize = 5;
    let events = stream(29, 4, 600);
    let mut fe = front_end(1 << 14, ShedPolicy::ShedOldest, wsize);
    let mut r = router(wsize);
    let h = fe.handle();
    let mut link = TransportLayer::new(
        TransportFaultPlan {
            loss: Some(SampleLoss { prob: 0.2 }),
            delay: Some(SampleDelay { prob: 0.3, max_hold: 4 }),
            duplication: Some(SampleDup { prob: 0.3 }),
            ..TransportFaultPlan::default()
        },
        7,
    );
    for (i, (t, s)) in events.iter().enumerate() {
        link.send(&h, *t, s.clone());
        if i % 8 == 7 {
            fe.pump(&mut r);
        }
    }
    link.flush(&h);
    fe.flush_transport(&mut r);

    let rep = link.report;
    assert!(rep.samples_dropped > 0, "the lossy link never dropped");
    let st = h.totals();
    // exact: every sent sample arrives exactly once unless dropped,
    // plus one extra submission per duplicate
    assert_eq!(
        st.submitted,
        link.sent_total() - rep.samples_dropped as u64
            + rep.samples_duplicated as u64
    );
    // injected ≥ observed: the consumer never reports more faults than
    // the link injected
    assert!(
        st.deduped
            <= (rep.samples_duplicated + rep.samples_delayed) as u64,
        "dedup hits {} vs injected {} dups + {} delays",
        st.deduped,
        rep.samples_duplicated,
        rep.samples_delayed
    );
    assert!(
        st.gaps_skipped
            <= (rep.samples_dropped + rep.samples_delayed) as u64,
        "write-offs {} vs injected {} drops + {} delays",
        st.gaps_skipped,
        rep.samples_dropped,
        rep.samples_delayed
    );
    assert!(st.gaps_skipped > 0, "drops must surface as gap write-offs");
    assert_eq!(st.resident, 0, "flush_transport left samples parked");
    assert_eq!(
        st.accepted + st.shed + st.deduped + st.closed_rejects + st.resident,
        st.submitted
    );
}
