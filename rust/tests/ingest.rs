//! Ingest front-end contract pins: explicit backpressure is *never*
//! silent, shed decisions are deterministic, concurrent producers under
//! `Block` lose nothing, and the front-end path into the router is
//! label-for-label identical to feeding the router directly.

use std::time::Duration;

use kermit::linalg::engine::Engine;
use kermit::monitor::MonitorConfig;
use kermit::stream::{
    IngestConfig, IngestFrontEnd, RouterConfig, ShedPolicy, StreamRouter,
    TenantId, TenantSample,
};
use kermit::workloadgen::{heavy_tailed_stream, Sample};

fn stream(seed: u64, tenants: usize, events: usize) -> Vec<(TenantId, Sample)> {
    heavy_tailed_stream(seed, tenants, events, 1.1, 4, &[0, 2, 5])
}

fn front_end(cap: usize, policy: ShedPolicy, wsize: usize) -> IngestFrontEnd {
    IngestFrontEnd::new(IngestConfig {
        queue_cap: cap,
        policy,
        monitor: MonitorConfig { window_size: wsize },
        drain_max: 0,
        engine: Engine::sequential(),
    })
}

fn router(wsize: usize) -> StreamRouter {
    StreamRouter::new(RouterConfig {
        monitor: MonitorConfig { window_size: wsize },
        ..RouterConfig::default()
    })
}

/// Conservation property: for every policy, every tenant's counters
/// reconcile exactly — `accepted + shed + resident == submitted` — and
/// every accepted sample is either inside a closed window or still open
/// in the batcher. No path loses a sample without counting it.
#[test]
fn accepted_plus_shed_equals_submitted_for_every_policy() {
    let wsize = 5;
    let events = stream(11, 8, 400);
    for policy in
        [ShedPolicy::Block, ShedPolicy::ShedOldest, ShedPolicy::ShedNewest]
    {
        // Block gets headroom so the single-threaded driver never
        // parks itself; the shed arms get a tiny queue so the
        // heavy-tailed head tenant overflows between pumps.
        let cap = if policy == ShedPolicy::Block { 64 } else { 4 };
        let mut fe = front_end(cap, policy, wsize);
        let mut r = router(wsize);
        let h = fe.handle();
        let mut windows = 0u64;
        for (i, (t, s)) in events.iter().enumerate() {
            h.submit(*t, s.clone());
            if i % 16 == 15 {
                windows += fe.pump(&mut r).windows;
            }
        }
        windows += fe.pump(&mut r).windows;
        assert_eq!(fe.resident(), 0, "{policy:?}: drain left residue");

        for (t, st) in h.stats() {
            assert_eq!(
                st.accepted + st.shed + st.resident,
                st.submitted,
                "{policy:?}: tenant {t:?} leaked samples"
            );
            assert_eq!(st.resident, 0, "{policy:?}: tenant {t:?} resident");
        }
        let totals = h.totals();
        assert_eq!(totals.submitted, events.len() as u64);
        assert_eq!(
            windows * wsize as u64 + fe.open_samples() as u64,
            totals.accepted,
            "{policy:?}: accepted samples do not reconcile with windows"
        );
        match policy {
            ShedPolicy::Block => assert_eq!(totals.shed, 0),
            _ => assert!(
                totals.shed > 0,
                "{policy:?}: tiny queue under a heavy tail must shed"
            ),
        }
    }
}

/// Shed decisions are a pure function of the submit/pump sequence:
/// replaying the identical single-threaded schedule yields the same
/// per-submit outcomes, the same per-tenant counters, and the same
/// published label sequences.
#[test]
fn shed_decisions_are_deterministic_across_identical_runs() {
    for policy in [ShedPolicy::ShedOldest, ShedPolicy::ShedNewest] {
        let run = || {
            let wsize = 4;
            let events = stream(42, 6, 300);
            let mut fe = front_end(3, policy, wsize);
            let mut r = router(wsize);
            let h = fe.handle();
            let mut outcomes = Vec::with_capacity(events.len());
            for (i, (t, s)) in events.iter().enumerate() {
                outcomes.push(h.submit(*t, s.clone()));
                if i % 10 == 9 {
                    fe.pump(&mut r);
                }
            }
            fe.pump(&mut r);
            let labels: Vec<(TenantId, Vec<u32>)> = r
                .tenants()
                .into_iter()
                .map(|t| (t, r.shard(t).unwrap().label_log()))
                .collect();
            (outcomes, h.stats(), labels)
        };
        let a = run();
        let b = run();
        assert_eq!(a.0, b.0, "{policy:?}: outcome sequences diverged");
        assert_eq!(a.1, b.1, "{policy:?}: tenant stats diverged");
        assert_eq!(a.2, b.2, "{policy:?}: label logs diverged");
    }
}

/// Two producer threads hammering cloned handles under `Block` while
/// the main thread pumps: every sample is eventually accepted — the
/// tiny queue forces real blocking, and nothing is shed or lost.
#[test]
fn two_producers_under_block_lose_nothing() {
    let wsize = 6;
    let events = stream(7, 10, 1_000);
    let mut fe = front_end(8, ShedPolicy::Block, wsize);
    let mut r = router(wsize);
    let handle = fe.handle();
    let mut windows = 0u64;
    std::thread::scope(|s| {
        let producers: Vec<_> = (0..2)
            .map(|p| {
                let h = handle.clone();
                let ev = &events;
                s.spawn(move || {
                    for (t, sample) in ev.iter().skip(p).step_by(2) {
                        h.submit(*t, sample.clone());
                    }
                })
            })
            .collect();
        // 10 tenants x cap 8 = 80 queue slots for 1000 events, and no
        // pump has run yet: a producer is guaranteed to fill a queue
        // and park. Wait for that (`blocked` is counted *before* the
        // wait) so the test provably exercises Block, then drain.
        while handle.totals().blocked == 0 {
            std::thread::yield_now();
        }
        loop {
            let st = fe.pump(&mut r);
            windows += st.windows;
            if producers.iter().all(|p| p.is_finished())
                && fe.resident() == 0
            {
                break;
            }
            if st.drained == 0 {
                fe.wait_for_samples(Duration::from_millis(1));
            }
        }
    });
    let totals = handle.totals();
    assert_eq!(totals.submitted, events.len() as u64);
    assert_eq!(totals.shed, 0);
    assert_eq!(totals.accepted, events.len() as u64);
    assert!(totals.blocked > 0, "cap 8 under a hot tenant must block");
    for (t, st) in handle.stats() {
        assert_eq!(st.accepted, st.submitted, "tenant {t:?}");
        assert_eq!(st.resident, 0, "tenant {t:?}");
    }
    assert_eq!(
        windows * wsize as u64 + fe.open_samples() as u64,
        events.len() as u64
    );
}

/// The batched front-end path is equivalent to feeding the router
/// directly: same tenants, same per-tenant contexts, regardless of
/// where the pump boundaries fall.
#[test]
fn front_end_path_matches_direct_router_ingest() {
    let wsize = 5;
    let events = stream(23, 5, 600);

    let mut direct = router(wsize);
    for (t, s) in &events {
        direct
            .ingest_tagged(&TenantSample { tenant: *t, sample: s.clone() });
    }
    direct.tick();

    let mut fe = front_end(1_024, ShedPolicy::Block, wsize);
    let mut batched = router(wsize);
    let h = fe.handle();
    for (i, (t, s)) in events.iter().enumerate() {
        h.submit(*t, s.clone());
        if i % 37 == 36 {
            fe.pump(&mut batched);
        }
    }
    fe.pump(&mut batched);

    assert_eq!(h.totals().shed, 0);
    assert_eq!(batched.tenants(), direct.tenants());
    for t in batched.tenants() {
        let a = batched.shard(t).unwrap();
        let b = direct.shard(t).unwrap();
        assert_eq!(a.contexts, b.contexts, "tenant {t:?} contexts diverged");
    }
}
