//! Multi-tenant equivalence pins (the stream-layer contract): routing K
//! interleaved tenant traces through a `StreamRouter` — with any engine
//! thread count — produces, per tenant, the **exact** context sequence
//! of replaying that tenant's trace alone through a sequential
//! `OnlinePipeline`. Per-shard state is single-writer and shards share
//! nothing mutable, so this is equality of every field (labels,
//! predictions, window indices, times), not a tolerance.

use kermit::features::ObservationWindow;
use kermit::knowledge::{Characterization, WorkloadDb};
use kermit::linalg::engine::Engine;
use kermit::monitor::{aggregate_samples, MonitorConfig};
use kermit::online::classifier::CentroidClassifier;
use kermit::online::{ContextStream, OnlinePipeline, WorkloadContext};
use kermit::stream::{
    interleave_round_robin, RouterConfig, StreamRouter, TenantId,
};
use kermit::workloadgen::{tenant_traces, Trace};
use std::sync::{Arc, Mutex};

const WINDOW: usize = 15;
const CLASSES: [u32; 4] = [0, 2, 5, 7];

/// A WorkloadDb with one entry per class, characterised from a clean
/// plateau of that class — so the centroid classifier has a stable,
/// deterministic model shared by the reference and the router paths.
fn class_db() -> WorkloadDb {
    use kermit::features::AnalyticWindow;
    use kermit::workloadgen::{tour_schedule, Generator};
    let mut db = WorkloadDb::new();
    for (i, &c) in CLASSES.iter().enumerate() {
        let mut g = Generator::with_default_config(1000 + i as u64);
        let t = g.generate(&tour_schedule(300, &[c]));
        let ws = aggregate_samples(
            &t.samples,
            &MonitorConfig { window_size: WINDOW },
        );
        let rows: Vec<Vec<f64>> = ws
            .iter()
            .map(|w| AnalyticWindow::from_observation(w).features)
            .collect();
        let ch = Characterization::from_vec_rows(&rows);
        let centroid = ch.mean_vector();
        db.insert_new(ch, centroid, rows.len(), false);
    }
    db
}

fn classifier(db: &WorkloadDb) -> Box<CentroidClassifier> {
    Box::new(CentroidClassifier::from_db(db, 20.0))
}

/// Sequential reference: this tenant's trace alone through one
/// aggregator + one pipeline.
fn replay_alone(trace: &Trace, db: &WorkloadDb) -> Vec<WorkloadContext> {
    let ctx = Arc::new(Mutex::new(ContextStream::new(64)));
    let mut pipe = OnlinePipeline::new(ctx);
    pipe.set_classifier(classifier(db));
    aggregate_samples(
        &trace.samples,
        &MonitorConfig { window_size: WINDOW },
    )
    .iter()
    .map(|w| pipe.observe(w))
    .collect()
}

fn route_interleaved(
    traces: &[Trace],
    db: &WorkloadDb,
    engine: Engine,
    burst: usize,
    tick_every: usize,
) -> Vec<Vec<WorkloadContext>> {
    let mut router = StreamRouter::new(RouterConfig {
        monitor: MonitorConfig { window_size: WINDOW },
        context_cap: 64,
        engine,
        ..Default::default()
    });
    // shards must exist (with the trained classifier installed) before
    // the first window closes
    for k in 0..traces.len() {
        router
            .add_tenant(TenantId(k as u32))
            .pipeline
            .set_classifier(classifier(db));
    }
    let mixed = interleave_round_robin(traces, burst);
    for (i, ts) in mixed.iter().enumerate() {
        router.ingest_tagged(ts);
        if (i + 1) % tick_every == 0 {
            router.tick();
        }
    }
    router.tick();
    (0..traces.len())
        .map(|k| router.shard(TenantId(k as u32)).unwrap().contexts.clone())
        .collect()
}

fn tenant_fleet(n: usize) -> Vec<Trace> {
    // mixed archetypes, hybrids, jittered durations — the adversarial
    // interleaving input, 5+ plateaus per tenant
    tenant_traces(42, n, 5, 8 * WINDOW, &CLASSES, 0, 0.0)
}

#[test]
fn router_equals_solo_replay_for_every_tenant_sequential_engine() {
    let db = class_db();
    let traces = tenant_fleet(5);
    let routed =
        route_interleaved(&traces, &db, Engine::sequential(), 11, 37);
    for (k, trace) in traces.iter().enumerate() {
        let solo = replay_alone(trace, &db);
        assert_eq!(
            routed[k], solo,
            "tenant {k}: routed context sequence diverged from solo replay"
        );
        assert!(!solo.is_empty());
        // the run must actually classify (a vacuous all-UNKNOWN
        // equality would prove nothing)
        assert!(
            solo.iter().any(|c| c.is_known()),
            "tenant {k} never classified"
        );
    }
}

#[test]
fn router_equals_solo_replay_under_engine_parallel_dispatch() {
    let db = class_db();
    let traces = tenant_fleet(6);
    let solos: Vec<Vec<WorkloadContext>> =
        traces.iter().map(|t| replay_alone(t, &db)).collect();
    for threads in [2, 4, 8] {
        let routed = route_interleaved(
            &traces,
            &db,
            Engine::with_threads(threads),
            7,
            53,
        );
        assert_eq!(
            routed, solos,
            "engine with {threads} threads diverged from solo replays"
        );
    }
}

#[test]
fn tick_granularity_does_not_change_the_context_sequences() {
    let db = class_db();
    let traces = tenant_fleet(4);
    // tick after every sample vs one giant tick at the end
    let fine =
        route_interleaved(&traces, &db, Engine::with_threads(4), 5, 1);
    let coarse = route_interleaved(
        &traces,
        &db,
        Engine::with_threads(4),
        5,
        usize::MAX,
    );
    assert_eq!(fine, coarse);
}

#[test]
fn per_tenant_windows_match_solo_aggregation_exactly() {
    // the monitor half of the contract: the router's shard aggregation
    // produces the same windows (indices, moments, truth) the batch
    // aggregator yields on the tenant's trace alone
    let traces = tenant_fleet(3);
    let mut router = StreamRouter::new(RouterConfig {
        monitor: MonitorConfig { window_size: WINDOW },
        context_cap: 64,
        engine: Engine::with_threads(3),
        ..Default::default()
    });
    for ts in interleave_round_robin(&traces, 13) {
        router.ingest_tagged(&ts);
    }
    router.tick();
    for (k, trace) in traces.iter().enumerate() {
        let solo: Vec<ObservationWindow> = aggregate_samples(
            &trace.samples,
            &MonitorConfig { window_size: WINDOW },
        );
        let routed = router
            .shard_mut(TenantId(k as u32))
            .unwrap();
        let got = std::mem::take(&mut routed.contexts);
        assert_eq!(got.len(), solo.len(), "tenant {k} window count");
        for (c, w) in got.iter().zip(&solo) {
            assert_eq!(c.window_index, w.index, "tenant {k}");
            assert_eq!(c.time, w.time, "tenant {k}");
        }
    }
}
