//! Integration pins for the telemetry plane:
//!
//! 1. the Prometheus renderer is pinned byte-for-byte against a golden
//!    exposition file (ordering, escaping, histogram layout — any
//!    format drift fails loudly instead of breaking scrapers quietly);
//! 2. rendering is deterministic and sorted regardless of
//!    registration order;
//! 3. histogram buckets render cumulatively and the `+Inf` bucket
//!    equals `_count`;
//! 4. the shared NaN-safe ratio helper backs every hit-ratio surface;
//! 5. end to end: a real multi-tenant tuning-plane run with telemetry
//!    and tracing enabled scrapes into a registry whose exposition the
//!    strict parser accepts, with live series from every layer — and
//!    the chaos alert catalog stays silent on the healthy run.

use kermit::experiments::tuning_plane::{plane_config, schedules, sim_config};
use kermit::obs::{
    chaos_rules, parse_prometheus, ratio, render_prometheus, snapshot_json,
    AlertEngine, Registry,
};
use kermit::online::PluginStats;
use kermit::simcluster::multi::MultiClusterEngine;
use kermit::simcluster::rm::ResourceManager;
use kermit::tuning::TuningPlane;

/// The registry the golden file pins. Values are chosen to be exact in
/// binary floating point so the rendering is stable everywhere.
fn golden_registry() -> Registry {
    let reg = Registry::new();
    reg.counter("kermit_demo_requests_total", "Requests served.", &[("tenant", "0")])
        .add(3);
    reg.counter("kermit_demo_requests_total", "Requests served.", &[("tenant", "1")])
        .add(5);
    reg.gauge("kermit_demo_pending", "Pending items.", &[]).set(2.5);
    let h = reg.histogram(
        "kermit_demo_latency_seconds",
        "Latency.",
        &[],
        &[1.0, 5.0, 25.0],
    );
    h.observe(0.5);
    h.observe(3.0);
    h.observe(50.0);
    reg.counter("kermit_demo_weird_total", "Weird labels.", &[("path", "a\"b\\c\nd")])
        .inc();
    reg
}

#[test]
fn exposition_matches_the_golden_file() {
    let rendered = render_prometheus(&golden_registry());
    let golden = include_str!("golden/exposition.prom");
    assert_eq!(
        rendered, golden,
        "render_prometheus drifted from tests/golden/exposition.prom; \
         if the format change is intentional, update the golden file"
    );
}

#[test]
fn families_and_series_render_sorted_regardless_of_registration_order() {
    // register in reverse name order, series in reverse label order
    let reg = Registry::new();
    reg.counter("kermit_z_total", "z", &[]).inc();
    reg.counter("kermit_a_total", "a", &[("tenant", "9")]).inc();
    reg.counter("kermit_a_total", "a", &[("tenant", "1")]).inc();
    let text = render_prometheus(&reg);
    let a = text.find("# TYPE kermit_a_total").unwrap();
    let z = text.find("# TYPE kermit_z_total").unwrap();
    assert!(a < z, "families not name-sorted:\n{text}");
    let t1 = text.find("tenant=\"1\"").unwrap();
    let t9 = text.find("tenant=\"9\"").unwrap();
    assert!(t1 < t9, "series not label-sorted:\n{text}");
    // and twice in a row is byte-identical
    assert_eq!(text, render_prometheus(&reg));
}

#[test]
fn histogram_buckets_are_cumulative_and_inf_equals_count() {
    let text = render_prometheus(&golden_registry());
    let bucket_of = |le: &str| -> f64 {
        let needle = format!("kermit_demo_latency_seconds_bucket{{le=\"{le}\"}} ");
        let line = text
            .lines()
            .find(|l| l.starts_with(&needle))
            .unwrap_or_else(|| panic!("no bucket le={le}:\n{text}"));
        line.rsplit(' ').next().unwrap().parse().unwrap()
    };
    let (b1, b5, b25, binf) = (
        bucket_of("1"),
        bucket_of("5"),
        bucket_of("25"),
        bucket_of("+Inf"),
    );
    assert!(b1 <= b5 && b5 <= b25 && b25 <= binf, "not cumulative");
    assert_eq!((b1, b5, b25, binf), (1.0, 2.0, 2.0, 3.0));
    let count_line = text
        .lines()
        .find(|l| l.starts_with("kermit_demo_latency_seconds_count "))
        .unwrap();
    let count: f64 = count_line.rsplit(' ').next().unwrap().parse().unwrap();
    assert_eq!(binf, count, "+Inf bucket != _count");
    // the strict parser agrees
    parse_prometheus(&text).expect("golden exposition parses strictly");
}

#[test]
fn hit_ratios_share_the_nan_safe_helper() {
    assert_eq!(ratio(0.0, 0.0), 0.0);
    assert_eq!(ratio(3.0, 4.0), 0.75);
    assert_eq!(ratio(1.0, f64::NAN), 0.0);
    assert_eq!(ratio(f64::INFINITY, 2.0), 0.0);
    // the zero-request plug-in reports 0.0, not NaN
    let stats = PluginStats::default();
    assert_eq!(stats.cache_hit_ratio(), 0.0);
}

/// End to end: telemetry and tracing on a real (small) multi-tenant
/// run. The scrape must produce a strictly valid exposition with live
/// series from the stream, plug-in, tuning and coordinator layers;
/// the decision trace must hold closed spans; the chaos alert catalog
/// must stay silent; and none of it may disturb the run itself.
#[test]
fn telemetry_scrapes_a_live_plane_into_valid_exposition() {
    let seed = 11;
    let mut plane = TuningPlane::new(plane_config(seed, 8));
    let reg = Registry::new();
    plane.enable_telemetry(&reg);
    plane.enable_tracing(256);

    let scheds = schedules(seed, 3, 8, &[0, 5]);
    let mut engine = MultiClusterEngine::new(
        ResourceManager::default_cluster(),
        sim_config(),
        seed,
    );
    let mut jobs_total = 0;
    for (t, jobs) in &scheds {
        plane.ensure_tenant(*t);
        engine.push_jobs(*t, jobs);
        jobs_total += jobs.len();
    }
    let sim = engine.run(&mut plane);
    plane.drain();
    plane.reconcile(sim.makespan + plane.resilience.decision_timeout + 1.0);
    plane.scrape(&reg);

    // the exposition is strictly valid and carries every layer
    let text = render_prometheus(&reg);
    let fams = parse_prometheus(&text).expect("live exposition parses");
    for prefix in ["kermit_stream_", "kermit_plugin_", "kermit_tuning_", "kermit_coordinator_"] {
        assert!(
            fams.iter().any(|f| f.name.starts_with(prefix)),
            "no {prefix} family in:\n{text}"
        );
    }
    // Algorithm-1 requests: one per job, summed over tenants
    assert_eq!(
        reg.total("kermit_plugin_requests_total"),
        Some(jobs_total as f64),
        "plug-in request counter diverged from the workload"
    );
    // the observe hot path really counted windows
    let windows = reg.total("kermit_stream_windows_observed_total").unwrap();
    assert!(windows > 0.0, "no windows counted:\n{text}");

    // scraping is idempotent: a second scrape changes nothing, so the
    // JSON snapshot is deterministic
    let snap_a = snapshot_json(&reg).encode_pretty();
    plane.scrape(&reg);
    let snap_b = snapshot_json(&reg).encode_pretty();
    assert_eq!(snap_a, snap_b, "scrape is not idempotent");

    // decision tracing captured the loop: spans opened, and completed
    // decisions closed with a measurement
    let trace = plane.decision_trace().expect("tracing enabled");
    assert_eq!(trace.open_spans(), 0, "spans left open after reconcile");
    let timeline = trace.timeline_json().encode();
    assert!(timeline.contains("\"tenants\""), "{timeline}");
    let measured = scheds.iter().any(|(t, _)| {
        trace
            .spans(t.0)
            .iter()
            .any(|s| s.outcome.as_deref() == Some("measured"))
    });
    assert!(measured, "no measured span in any tenant timeline");

    // a healthy run never pages: two alert evaluations over the final
    // registry state produce no events
    let mut alerts = AlertEngine::new(chaos_rules());
    assert!(alerts.eval(&reg, 1.0).is_empty());
    assert!(alerts.eval(&reg, 2.0).is_empty());
    assert!(alerts.active().is_empty());
}
