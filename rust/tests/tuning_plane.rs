//! Integration pins for the per-tenant tuning plane PR:
//!
//! 1. the consolidated off-line cycle: a multi-tenant cycle produces
//!    the same DB and classifier state as the single-tenant cycle on an
//!    identical backlog (including ZSL synthesis and transition
//!    training, which the old multi-tenant path silently skipped);
//! 2. the closed loop end to end: a K=4 tuning-plane run where a
//!    tenant's converged optimum is reused by the others.

use kermit::coordinator::{
    CadencePolicy, Coordinator, CoordinatorConfig, MultiTenantCoordinator,
};
use kermit::monitor::{aggregate_samples, MonitorConfig};
use kermit::stream::TenantId;
use kermit::workloadgen::{tour_schedule, Generator, Trace};

fn trace(seed: u64, classes: &[u32], dur: usize) -> Trace {
    let mut g = Generator::with_default_config(seed);
    g.generate(&tour_schedule(dur, classes))
}

/// The consolidation pin: same backlog, same seed -> identical DB JSON
/// and identical pipeline behaviour (labels AND transition naming) from
/// the single-tenant and the multi-tenant off-line cycles.
#[test]
fn multi_tenant_cycle_matches_single_tenant_on_identical_backlog() {
    let mut cfg = CoordinatorConfig::default();
    // manual off-line only: the comparison drives one explicit cycle
    cfg.offline_interval_windows = 1_000_000;
    cfg.seed = 1;

    // both directions twice so two transition types exist (0->5, 5->0)
    let learn = trace(1, &[0, 5, 0, 5], 180);

    let mut single = Coordinator::new(cfg.clone());
    single.ingest(&learn.samples);
    single.run_offline();

    let mut multi = MultiTenantCoordinator::new(cfg.clone());
    let t0 = TenantId(0);
    multi.ingest(t0, &learn.samples);
    multi.tick();
    multi.run_offline();

    // identical knowledge plane, including the ZSL-synthesised classes
    // the old multi-tenant cycle never created
    let single_db = single.db.read().unwrap().to_json().encode_pretty();
    let multi_db = multi.db.read().unwrap().to_json().encode_pretty();
    assert_eq!(single_db, multi_db, "DB state diverged");
    assert!(
        multi.db.read().unwrap().entries().any(|e| e.synthetic),
        "multi-tenant cycle skipped ZSL synthesis"
    );
    assert!(
        multi.has_transition_model(),
        "multi-tenant cycle skipped transition training"
    );

    // identical classifier behaviour: replay a fresh trace through the
    // single pipeline and the tenant shard's pipeline and compare the
    // full label sequences and the on-line transition naming
    let fresh = trace(9, &[5, 0, 5], 150);
    let windows = aggregate_samples(
        &fresh.samples,
        &MonitorConfig { window_size: 30 },
    );
    let single_labels: Vec<u32> = windows
        .iter()
        .map(|w| single.pipeline.observe(w).current_label)
        .collect();
    let shard = multi.router_mut().shard_mut(t0).unwrap();
    let multi_labels: Vec<u32> = windows
        .iter()
        .map(|w| shard.pipeline.observe(w).current_label)
        .collect();
    assert_eq!(single_labels, multi_labels, "label sequences diverged");
    assert_eq!(
        single.pipeline.transition_log, shard.pipeline.transition_log,
        "transition naming diverged"
    );
    // sanity: the comparison exercised real classifications
    assert!(
        single_labels.iter().any(|&l| l != kermit::online::UNKNOWN),
        "nothing classified; the parity check is vacuous"
    );
}

/// Adaptive cadence wiring is reachable from the public config surface.
#[test]
fn adaptive_cadence_is_config_driven() {
    let mut cfg = CoordinatorConfig::default();
    cfg.offline_interval_windows = 1_000_000;
    let mut coord = MultiTenantCoordinator::new(cfg);
    coord.cadence =
        CadencePolicy::Adaptive { unknown_rate: 0.5, min_windows: 4 };
    let t = trace(3, &[2, 7], 240);
    coord.ingest(TenantId(0), &t.samples);
    coord.tick();
    assert!(
        coord.offline_runs >= 1,
        "UNKNOWN pressure never triggered a cycle"
    );
}

/// End-to-end closed loop at K=4: run the tuning plane on real job
/// streams (shared simcluster, per-tenant plug-ins, adaptive cadence)
/// and check the cross-tenant reuse economics surfaced in the report.
#[test]
fn k4_tuning_plane_run_reuses_optima_across_tenants() {
    let scheds = kermit::experiments::tuning_plane::schedules(
        11, 4, 12, &[0, 5],
    );
    let report =
        kermit::experiments::tuning_plane::run_shared(11, &scheds, 14);
    // (avoid Debug-printing the whole report: it embeds every sample)
    let summary = format!(
        "makespan {:.0}, concurrency {}, searches {}, abandoned {}, \
         cross hits {}, probes {}",
        report.sim.makespan,
        report.sim.peak_concurrency,
        report.searches_completed,
        report.searches_abandoned,
        report.cross_tenant_hits,
        report.probes_paid,
    );
    assert!(report.sim.peak_concurrency >= 2, "{summary}");
    assert!(report.searches_completed >= 1, "{summary}");
    assert!(report.cross_tenant_hits >= 1, "{summary}");
    assert!(report.cache_hit_ratio() > 0.0, "{summary}");
    // per-tenant stats surfaced in the multi-tenant report
    assert_eq!(report.multi.tenant_stats.len(), 4);
    let requests: usize = report
        .multi
        .tenant_stats
        .iter()
        .map(|(_, s)| s.requests)
        .sum();
    assert_eq!(requests, 4 * 12, "every job made one Algorithm-1 request");
}
